//! Eq. (7)/(8) fast thermal model: per-vertical-stack cumulative resistive
//! heating, used as the MOO temperature objective (the detailed grid solver
//! validates Pareto winners per Eq. (10)).
//!
//! For a tile at tier k of stack n:
//!     T(d,t) = max_{n,k} { sum_{i<=k} P_{n,i}(t) * sum_{j<=i} R_j
//!                          + R_b * sum_{i<=k} P_{n,i}(t) } * T_H
//! Because every term is non-negative, the max over k is attained at the top
//! tier, so the per-stack score reduces to
//!     T_n = sum_i P_{n,i} * (Rcum(i) + R_b),
//! which is what the `cth` coefficient vector encodes per tile position:
//! cth[pos] = (Rcum(tier(pos)) + R_b) * T_H.  The kernel (and the native
//! mirror) then compute max_n sum over the stack.

use super::materials::LayerStack;

/// Eq.(7) coefficients for one technology.
#[derive(Debug, Clone)]
pub struct StackModel {
    /// Per-tier cumulative vertical resistance Rcum(tier) + R_b [K/W],
    /// already scaled by the lateral-heat-flow factor T_H.
    pub coeff_per_tier: Vec<f64>,
    /// Lateral heat-flow factor (dimensionless, calibrated vs grid solver).
    pub t_h: f64,
}

impl StackModel {
    /// Derive per-tier coefficients from the physical stack by solving the
    /// 1D ladder network of one stack column exactly (a 2x2-cell footprint
    /// at the thermal-grid resolution): vertical conductances between
    /// layers, the sink at the bottom, and — crucially for cooled TSV —
    /// the microfluidic ambient shunts at the bonding layers.
    ///
    /// `coeff_per_tier[i]` is the temperature rise of the TOP tier per watt
    /// injected at tier `i` (the Eq. (7) "max over k" is attained at the
    /// top for a dry stack; with shunts the top-row transfer coefficients
    /// remain the consistent additive surrogate).  `t_h` folds the lateral
    /// spreading that only the grid solver resolves (calibrated in
    /// `tests/thermal_xval.rs`).
    pub fn from_stack(stack: &LayerStack, t_h: f64) -> Self {
        let cells_per_tile_col = 4.0;
        let z = stack.z();
        let gdn: Vec<f64> = stack.gdn().iter().map(|g| g * cells_per_tile_col).collect();
        let gup: Vec<f64> = stack.gup().iter().map(|g| g * cells_per_tile_col).collect();
        let gamb: Vec<f64> = stack.gamb().iter().map(|g| g * cells_per_tile_col).collect();

        // Conductance matrix of the ladder: G[i][i] = sum of couplings,
        // G[i][j] = -g between neighbours; ambient is ground.
        let mut g = vec![vec![0.0f64; z]; z];
        for i in 0..z {
            let up = if i + 1 < z { gup[i] } else { 0.0 };
            g[i][i] = gdn[i] + up + gamb[i];
            if i + 1 < z {
                g[i][i + 1] = -gup[i];
                g[i + 1][i] = -gup[i];
            }
        }

        // Solve G * t = e_src for each tier source; read the top tier row.
        let top = stack.tier_layer(3.min(3));
        let mut coeff = Vec::with_capacity(4);
        for tier in 0..4 {
            let src = stack.tier_layer(tier);
            let mut rhs = vec![0.0f64; z];
            rhs[src] = 1.0;
            let t = solve_dense(&g, &rhs);
            coeff.push(t[top] * t_h);
        }
        StackModel { coeff_per_tier: coeff, t_h }
    }

    /// The `cth` artifact input: coefficient per tile *position*.
    ///
    /// `tier_of_pos[p]` maps each of the N positions to its logic tier.
    pub fn cth(&self, tier_of_pos: &[usize]) -> Vec<f32> {
        tier_of_pos
            .iter()
            .map(|&t| self.coeff_per_tier[t] as f32)
            .collect()
    }

    /// Fast Eq.(7)+(8) evaluation in pure Rust: peak rise over ambient.
    ///
    /// `power[w][pos]` per window; `stack_of_pos` / `tier_of_pos` give the
    /// static geometry.
    pub fn peak_rise(
        &self,
        power: &[Vec<f64>],
        stack_of_pos: &[usize],
        tier_of_pos: &[usize],
        n_stacks: usize,
    ) -> f64 {
        let mut tmax = 0.0f64;
        for pw in power {
            let mut per_stack = vec![0.0f64; n_stacks];
            for (pos, &p) in pw.iter().enumerate() {
                per_stack[stack_of_pos[pos]] += p * self.coeff_per_tier[tier_of_pos[pos]];
            }
            for &t in &per_stack {
                tmax = tmax.max(t);
            }
        }
        tmax
    }
}

/// Gaussian elimination with partial pivoting (small dense systems; the
/// ladder is Z=10).
fn solve_dense(a: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    let mut m: Vec<Vec<f64>> = a.iter().cloned().collect();
    let mut x = b.to_vec();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
            .unwrap();
        m.swap(col, piv);
        x.swap(col, piv);
        let d = m[col][col];
        debug_assert!(d.abs() > 1e-15, "singular ladder matrix");
        for row in (col + 1)..n {
            let f = m[row][col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                m[row][k] -= f * m[col][k];
            }
            x[row] -= f * x[col];
        }
    }
    for col in (0..n).rev() {
        x[col] /= m[col][col];
        for row in 0..col {
            x[row] -= m[row][col] * x[col];
            m[row][col] = 0.0;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thermal::materials::LayerStack;

    fn geo() -> (Vec<usize>, Vec<usize>) {
        // 16 positions: 4 stacks x 4 tiers (toy version of the 64-tile chip).
        let mut stack_of = Vec::new();
        let mut tier_of = Vec::new();
        for tier in 0..4 {
            for s in 0..4 {
                stack_of.push(s);
                tier_of.push(tier);
            }
        }
        (stack_of, tier_of)
    }

    #[test]
    fn coefficients_increase_with_tier() {
        for s in [LayerStack::tsv(false), LayerStack::m3d()] {
            let m = StackModel::from_stack(&s, 1.0);
            for t in 1..4 {
                assert!(m.coeff_per_tier[t] > m.coeff_per_tier[t - 1]);
            }
        }
    }

    #[test]
    fn tsv_coefficients_dominate_m3d() {
        let tsv = StackModel::from_stack(&LayerStack::tsv(false), 1.0);
        let m3d = StackModel::from_stack(&LayerStack::m3d(), 1.0);
        // Above the base, the TSV bonding resistance accumulates; tier 3 of
        // TSV must be far worse than tier 3 of M3D relative to tier 0.
        let tsv_span = tsv.coeff_per_tier[3] - tsv.coeff_per_tier[0];
        let m3d_span = m3d.coeff_per_tier[3] - m3d.coeff_per_tier[0];
        assert!(
            tsv_span > 20.0 * m3d_span,
            "tsv span {tsv_span} vs m3d span {m3d_span}"
        );
    }

    #[test]
    fn hot_tile_on_top_tier_is_worse() {
        let m = StackModel::from_stack(&LayerStack::tsv(false), 1.0);
        let (stack_of, tier_of) = geo();
        // 1 W on a tier-0 position vs the same watt on tier 3.
        let mut p_low = vec![vec![0.0; 16]];
        p_low[0][0] = 1.0; // tier 0, stack 0
        let mut p_high = vec![vec![0.0; 16]];
        p_high[0][12] = 1.0; // tier 3, stack 0
        let low = m.peak_rise(&p_low, &stack_of, &tier_of, 4);
        let high = m.peak_rise(&p_high, &stack_of, &tier_of, 4);
        assert!(high > low);
    }

    #[test]
    fn peak_takes_worst_window_and_stack() {
        let m = StackModel::from_stack(&LayerStack::m3d(), 1.0);
        let (stack_of, tier_of) = geo();
        let mut w0 = vec![0.0; 16];
        w0[1] = 1.0; // mild
        let mut w1 = vec![0.0; 16];
        w1[13] = 5.0; // hot window, top tier
        let peak = m.peak_rise(&[w0.clone(), w1.clone()], &stack_of, &tier_of, 4);
        let only_mild = m.peak_rise(&[w0], &stack_of, &tier_of, 4);
        assert!(peak > only_mild);
    }

    #[test]
    fn cth_maps_positions_through_tiers() {
        let m = StackModel::from_stack(&LayerStack::m3d(), 2.0);
        let cth = m.cth(&[0, 3, 1]);
        assert_eq!(cth.len(), 3);
        assert!((cth[0] - m.coeff_per_tier[0] as f32).abs() < 1e-9);
        assert!((cth[1] - m.coeff_per_tier[3] as f32).abs() < 1e-9);
    }
}
