//! Physical layer stacks for TSV- and M3D-based 4-tier chips (paper Table 1,
//! magnitudes from Samal et al. [5]) and their reduction to the thermal-grid
//! conductance vectors used by both the L1 kernel and the native solver.
//!
//! Layer order is z = 0 nearest the heat sink (the paper places the sink
//! below the base layer; "tiles near the sink" = low tier index).

/// One material layer of the vertical stack.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Layer name (e.g. `"si_t0"`, `"bond_01"`).
    pub name: &'static str,
    /// Thickness [m].
    pub thickness: f64,
    /// Thermal conductivity [W/(m K)].
    pub k: f64,
    /// Volumetric heat capacity [J/(m^3 K)] — the transient stepper's
    /// per-cell thermal mass; irrelevant at steady state.
    pub cv: f64,
    /// If this is an active silicon layer: which logic tier (0..4) it hosts.
    pub tier: Option<usize>,
}

/// A full vertical stack plus lateral cell geometry.
#[derive(Debug, Clone)]
pub struct LayerStack {
    /// Layers ordered bottom (sink side, z = 0) to top.
    pub layers: Vec<Layer>,
    /// Lateral cell pitch [m] (square cells).
    pub cell_pitch: f64,
    /// Heat-sink thermal resistance seen by ONE grid cell [K/W].
    pub r_sink_cell: f64,
    /// Convective shunt to ambient per inter-tier layer cell [W/K]
    /// (microfluidic cooling [20]); 0.0 for a dry stack.
    pub g_cool_cell: f64,
}

fn si(name: &'static str, thickness: f64, tier: usize) -> Layer {
    // Bulk silicon conductivity; thinned dies keep ~130 W/mK at die scale.
    // cv = rho * cp = 2330 kg/m^3 * 700 J/(kg K).
    Layer { name, thickness, k: 130.0, cv: 1.63e6, tier: Some(tier) }
}

impl LayerStack {
    /// TSV stack: 4 thinned planar dies (~100 um Si) glued with a
    /// low-conductivity bonding polymer (BCB-like, k ~ 0.3 W/mK) [5].
    /// `cooled` enables the microfluidic inter-tier channels the paper uses
    /// for both TSV-PO and TSV-PT.
    pub fn tsv(cooled: bool) -> Self {
        // BCB-like adhesive: rho ~ 1050 kg/m^3, cp ~ 2180 J/(kg K).
        let bond = |name| Layer { name, thickness: 12e-6, k: 0.42, cv: 2.3e6, tier: None };
        LayerStack {
            layers: vec![
                Layer { name: "base", thickness: 200e-6, k: 130.0, cv: 1.63e6, tier: None },
                si("si_t0", 100e-6, 0),
                bond("bond_01"),
                si("si_t1", 100e-6, 1),
                bond("bond_12"),
                si("si_t2", 100e-6, 2),
                bond("bond_23"),
                si("si_t3", 100e-6, 3),
                Layer { name: "beol", thickness: 12e-6, k: 2.25, cv: 2.0e6, tier: None },
                Layer { name: "passiv", thickness: 20e-6, k: 1.4, cv: 1.6e6, tier: None },
            ],
            cell_pitch: 1.0e-3,
            r_sink_cell: 16.0, // TSV: thick die stack + TIM to the sink
            g_cool_cell: if cooled { 0.027 } else { 0.0 },
        }
    }

    /// M3D stack: sequentially fabricated thin tiers (~ 1 um of device
    /// silicon) separated by a sub-micron ILD with good thermal contact [5].
    /// No bonding adhesive anywhere; no liquid cooling needed.
    pub fn m3d() -> Self {
        // SiO2-like ILD: rho ~ 2200 kg/m^3, cp ~ 730 J/(kg K).
        let ild = |name| Layer { name, thickness: 0.30e-6, k: 1.4, cv: 1.6e6, tier: None };
        LayerStack {
            layers: vec![
                Layer { name: "base", thickness: 200e-6, k: 130.0, cv: 1.63e6, tier: None },
                si("si_t0", 3e-6, 0),
                ild("ild_01"),
                si("si_t1", 3e-6, 1),
                ild("ild_12"),
                si("si_t2", 3e-6, 2),
                ild("ild_23"),
                si("si_t3", 3e-6, 3),
                Layer { name: "beol", thickness: 6e-6, k: 2.25, cv: 2.0e6, tier: None },
                Layer { name: "passiv", thickness: 20e-6, k: 1.4, cv: 1.6e6, tier: None },
            ],
            cell_pitch: 1.0e-3,
            r_sink_cell: 5.0, // M3D: thin stack, low-resistance sink path
            g_cool_cell: 0.0,
        }
    }

    /// Number of layers (the grid Z dimension).
    pub fn z(&self) -> usize {
        self.layers.len()
    }

    /// Grid-cell z index hosting logic tier `t`.
    pub fn tier_layer(&self, t: usize) -> usize {
        self.layers
            .iter()
            .position(|l| l.tier == Some(t))
            .expect("tier not in stack")
    }

    /// Vertical conductance between layer z and z-1 per cell [W/K]
    /// (series half-thickness model); z = 0 couples to the heat sink.
    pub fn gdn(&self) -> Vec<f64> {
        let a = self.cell_pitch * self.cell_pitch;
        (0..self.z())
            .map(|z| {
                let half = |l: &Layer| l.thickness / (2.0 * l.k * a);
                if z == 0 {
                    1.0 / (half(&self.layers[0]) + self.r_sink_cell)
                } else {
                    1.0 / (half(&self.layers[z]) + half(&self.layers[z - 1]))
                }
            })
            .collect()
    }

    /// Vertical conductance between layer z and z+1 (symmetric with gdn).
    pub fn gup(&self) -> Vec<f64> {
        let gdn = self.gdn();
        (0..self.z())
            .map(|z| if z + 1 < self.z() { gdn[z + 1] } else { 0.0 })
            .collect()
    }

    /// Lateral conductance between adjacent cells of each layer [W/K]:
    /// k * t * w / w = k * t for square cells.
    pub fn glat(&self) -> Vec<f64> {
        self.layers.iter().map(|l| l.k * l.thickness).collect()
    }

    /// Per-cell heat capacity of each layer [J/K]: `cv * thickness * A`.
    /// The transient stepper divides this by `dt` to form the implicit-Euler
    /// self term; steady-state solves never read it.
    pub fn cap(&self) -> Vec<f64> {
        let a = self.cell_pitch * self.cell_pitch;
        self.layers.iter().map(|l| l.cv * l.thickness * a).collect()
    }

    /// Convective ambient shunt per layer [W/K]: non-zero only at the
    /// inter-tier layers when liquid cooling is active.
    pub fn gamb(&self) -> Vec<f64> {
        self.layers
            .iter()
            .map(|l| {
                if l.tier.is_none() && l.name.starts_with("bond") {
                    self.g_cool_cell
                } else {
                    0.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_stacks_have_ten_layers_and_four_tiers() {
        for s in [LayerStack::tsv(true), LayerStack::m3d()] {
            assert_eq!(s.z(), 10);
            for t in 0..4 {
                let z = s.tier_layer(t);
                assert_eq!(s.layers[z].tier, Some(t));
            }
        }
    }

    #[test]
    fn m3d_intertier_conductance_dominates_tsv() {
        // The bonding layer is the TSV bottleneck (paper Fig 4): the
        // conductance between tier silicon layers must be orders of
        // magnitude higher in M3D.
        let tsv = LayerStack::tsv(true);
        let m3d = LayerStack::m3d();
        let g_tsv = tsv.gdn()[tsv.tier_layer(1)]; // si_t1 -> bond_01 side
        let g_m3d = m3d.gdn()[m3d.tier_layer(1)];
        assert!(
            g_m3d > 20.0 * g_tsv,
            "expected M3D >> TSV inter-tier conductance: {g_m3d} vs {g_tsv}"
        );
    }

    #[test]
    fn cooling_only_touches_bond_layers() {
        let tsv = LayerStack::tsv(true);
        let gamb = tsv.gamb();
        for (z, l) in tsv.layers.iter().enumerate() {
            if l.name.starts_with("bond") {
                assert!(gamb[z] > 0.0);
            } else {
                assert_eq!(gamb[z], 0.0);
            }
        }
        assert!(LayerStack::tsv(false).gamb().iter().all(|&g| g == 0.0));
        assert!(LayerStack::m3d().gamb().iter().all(|&g| g == 0.0));
    }

    #[test]
    fn per_cell_capacity_is_positive_and_m3d_tiers_are_light() {
        // Every layer carries thermal mass, and an M3D device tier (3 um)
        // holds far less heat than a thinned TSV die (100 um) — the physics
        // behind M3D's faster transients.
        let tsv = LayerStack::tsv(true);
        let m3d = LayerStack::m3d();
        assert!(tsv.cap().iter().all(|&c| c > 0.0));
        assert!(m3d.cap().iter().all(|&c| c > 0.0));
        let c_tsv = tsv.cap()[tsv.tier_layer(1)];
        let c_m3d = m3d.cap()[m3d.tier_layer(1)];
        assert!(
            c_tsv > 20.0 * c_m3d,
            "expected TSV tier thermal mass >> M3D: {c_tsv} vs {c_m3d}"
        );
    }

    #[test]
    fn gup_is_shifted_gdn() {
        let s = LayerStack::m3d();
        let gdn = s.gdn();
        let gup = s.gup();
        for z in 0..s.z() - 1 {
            assert_eq!(gup[z], gdn[z + 1]);
        }
        assert_eq!(gup[s.z() - 1], 0.0);
    }
}
