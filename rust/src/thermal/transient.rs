//! Transient thermal stepping + DTM (DVFS/throttling) scenarios
//! (DESIGN.md §13).
//!
//! [`TransientPlan`] extends the zero-allocation solve plan to the time
//! domain with implicit (backward) Euler:
//!
//! ```text
//! C dT/dt = P - G T      =>      (G + C/dt) T_{n+1} = P + (C/dt) T_n
//! ```
//!
//! The per-cell capacitance term `C/dt` enters the system matrix exactly
//! like an ambient shunt: `gamb[z]` appears only in the Jacobi / residual
//! denominators and the coarse-level sink sum, so a [`ThermalSolver`] built
//! over a grid with `gamb[z] += cap[z]/dt` *is* the implicit-Euler system —
//! the whole two-grid machinery (and its zero-allocation contract) is
//! reused unchanged.  Each step solves that system with effective power
//! `P + (C/dt) T_n`; at a fixed point (`T_{n+1} = T_n`) the capacitance
//! terms cancel and the state satisfies the steady equation `G T = P`, so
//! stepping to t→∞ reproduces the steady plan solve (golden-tested on all
//! three stacks in `tests/thermal_transient.rs`).
//!
//! On top of the stepper sits the DTM scenario family: a [`Controller`]
//! maps (step index, last simulated peak temperature) to a power scale in
//! `[0, 1]` — threshold throttling, sprint-and-rest duty cycles, or none —
//! and [`simulate_with`] runs the closed loop over a cycling window
//! schedule, reporting [`TransientStats`] (peak/final temperature,
//! time-over-threshold, sustained throughput fraction).  A first-order RC
//! reduction ([`cheap_transient`]) applies the same controller semantics to
//! the Eq.(7) stack-model rises on the DSE score hot path.

use super::grid::{GridParams, ThermalGrid};
use super::materials::LayerStack;
use super::plan::ThermalSolver;

/// DTM power controller: a pure function of (step index, last peak
/// temperature) so simulations are deterministic and replayable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Controller {
    /// No DTM: full power every step.
    None,
    /// Bang-bang thermostat: whenever the last simulated peak temperature
    /// reaches `trip_c`, scale power to `relief` (< 1) for the next step.
    Throttle {
        /// Trip temperature [°C].
        trip_c: f64,
        /// Power scale applied while tripped (clamped to `[0, 1]`).
        relief: f64,
    },
    /// Open-loop duty cycle: `sprint_steps` at full power, then
    /// `rest_steps` at `rest_scale`, repeating.
    SprintRest {
        /// Full-power steps per period.
        sprint_steps: u32,
        /// Reduced-power steps per period.
        rest_steps: u32,
        /// Power scale during rest (clamped to `[0, 1]`).
        rest_scale: f64,
    },
}

impl Controller {
    /// Power scale for step `step` given the last simulated peak
    /// temperature; always in `[0, 1]` (the throttled-power invariant
    /// pinned by `tests/prop_transient.rs`).
    pub fn scale(&self, step: usize, last_peak_c: f64) -> f64 {
        let s = match *self {
            Controller::None => 1.0,
            Controller::Throttle { trip_c, relief } => {
                if last_peak_c >= trip_c {
                    relief
                } else {
                    1.0
                }
            }
            Controller::SprintRest { sprint_steps, rest_steps, rest_scale } => {
                let period = (sprint_steps + rest_steps).max(1) as usize;
                if step % period < sprint_steps as usize {
                    1.0
                } else {
                    rest_scale
                }
            }
        };
        s.clamp(0.0, 1.0)
    }

    /// Canonical short description — the leg-identity / log spelling
    /// (`none`, `throttle:85,0.7`, `sprint-rest:6,2,0.5`).
    pub fn desc(&self) -> String {
        match *self {
            Controller::None => "none".into(),
            Controller::Throttle { trip_c, relief } => format!("throttle:{trip_c},{relief}"),
            Controller::SprintRest { sprint_steps, rest_steps, rest_scale } => {
                format!("sprint-rest:{sprint_steps},{rest_steps},{rest_scale}")
            }
        }
    }
}

/// Transient scenario configuration.  `horizon_s <= 0` or `dt_s <= 0`
/// disables the scenario entirely (the steady path is the horizon-0
/// special case, mirroring `--variation-sigma 0`).
#[derive(Debug, Clone, PartialEq)]
pub struct TransientConfig {
    /// Simulated horizon [s].
    pub horizon_s: f64,
    /// Implicit-Euler step [s] (unconditionally stable for any `dt`; the
    /// step only controls time resolution, not stability).
    pub dt_s: f64,
    /// DTM controller applied to the power trace.
    pub controller: Controller,
    /// Ambient temperature [°C] for absolute-temperature readouts.
    pub ambient_c: f64,
}

impl Default for TransientConfig {
    fn default() -> Self {
        TransientConfig {
            horizon_s: 0.08,
            dt_s: 2.0e-3,
            controller: Controller::None,
            ambient_c: super::T_AMBIENT_C,
        }
    }
}

impl TransientConfig {
    /// Whether the scenario does anything; disabled configs are
    /// bit-identical to the nominal (steady) path.
    pub fn enabled(&self) -> bool {
        self.horizon_s > 0.0 && self.dt_s > 0.0
    }

    /// Number of implicit-Euler steps covering the horizon (at least 1
    /// when enabled).
    pub fn steps(&self) -> usize {
        if !self.enabled() {
            return 0;
        }
        (self.horizon_s / self.dt_s).ceil().max(1.0) as usize
    }
}

/// Summary of one transient simulation (absolute temperatures).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransientStats {
    /// Peak temperature over the horizon [°C].
    pub peak_c: f64,
    /// Peak temperature at the final step [°C].
    pub final_c: f64,
    /// Time spent with peak temperature above the threshold [s].
    pub time_over_s: f64,
    /// Mean controller power scale over the horizon (1.0 = never
    /// throttled); sustained throughput relative to the burst trace.
    pub sustained_frac: f64,
}

/// A reusable implicit-Euler stepping plan for one `(stack, grid shape,
/// dt)` triple.
///
/// Build once with [`TransientPlan::new`] / [`TransientPlan::for_stack`],
/// then call [`step_into`](TransientPlan::step_into) /
/// [`step_scaled`](TransientPlan::step_scaled) any number of times — zero
/// heap allocations per step (pinned by a counting-allocator test in
/// `tests/thermal_transient.rs`).
#[derive(Debug, Clone)]
pub struct TransientPlan {
    solver: ThermalSolver,
    /// Per-layer `cap[z] / dt` [W/K].
    cap_dt: Vec<f64>,
    dt: f64,
    /// State: temperature rise after the last step (starts at 0 = ambient).
    t_prev: Vec<f64>,
    /// Scratch: effective power `P + (C/dt) T_n`.
    p_eff: Vec<f64>,
    /// Scratch: solve output for the peak-returning entry points.
    out: Vec<f64>,
}

impl TransientPlan {
    /// Build the plan: the solver is constructed over a copy of `grid`
    /// with `gamb[z] += cap[z]/dt`, which is exactly the implicit-Euler
    /// system matrix `G + C/dt`.
    pub fn new(grid: &ThermalGrid, cap: &[f64], dt: f64) -> Self {
        assert!(dt > 0.0, "transient step must be positive");
        assert_eq!(cap.len(), grid.z, "one capacitance per layer");
        let cap_dt: Vec<f64> = cap.iter().map(|&c| c / dt).collect();
        let mut sys = grid.clone();
        for (g, &cdt) in sys.params.gamb.iter_mut().zip(cap_dt.iter()) {
            *g += cdt;
        }
        let solver = ThermalSolver::new(&sys);
        let cells = solver.cells();
        TransientPlan {
            solver,
            cap_dt,
            dt,
            t_prev: vec![0.0; cells],
            p_eff: vec![0.0; cells],
            out: vec![0.0; cells],
        }
    }

    /// Plan for a physical stack on an `(ny, nx)` lateral grid.
    pub fn for_stack(stack: &LayerStack, ny: usize, nx: usize, dt: f64) -> Self {
        let grid = ThermalGrid::new(stack.z(), ny, nx, GridParams::from_stack(stack));
        TransientPlan::new(&grid, &stack.cap(), dt)
    }

    /// Cells per step (`z * y * x`).
    pub fn cells(&self) -> usize {
        self.solver.cells()
    }

    /// The implicit-Euler step [s].
    pub fn dt(&self) -> f64 {
        self.dt
    }

    /// Current state: temperature rise per cell after the last step.
    pub fn state(&self) -> &[f64] {
        &self.t_prev
    }

    /// Reset the state to ambient (rise 0 everywhere).
    pub fn reset(&mut self) {
        self.t_prev.fill(0.0);
    }

    /// One implicit-Euler step under power `pow_`, writing the new
    /// temperature-rise field into `out` (which also becomes the state for
    /// the next step).  Zero heap allocations.
    pub fn step_into(&mut self, pow_: &[f64], it3d: usize, out: &mut [f64]) {
        self.fill_effective_power(pow_, 1.0);
        self.solver.solve_into(&self.p_eff, it3d, out);
        self.t_prev.copy_from_slice(out);
    }

    /// One step under `scale * pow_` (the DTM-scaled trace), returning the
    /// peak temperature rise.  Zero heap allocations.
    pub fn step_scaled(&mut self, pow_: &[f64], scale: f64, it3d: usize) -> f64 {
        self.fill_effective_power(pow_, scale);
        let mut out = std::mem::take(&mut self.out);
        self.solver.solve_into(&self.p_eff, it3d, &mut out);
        self.t_prev.copy_from_slice(&out);
        let peak = out.iter().copied().fold(f64::MIN, f64::max);
        self.out = out;
        peak
    }

    /// `p_eff = scale * P + (C/dt) T_n`, per layer plane.
    fn fill_effective_power(&mut self, pow_: &[f64], scale: f64) {
        let cells = self.cells();
        assert_eq!(pow_.len(), cells, "power grid size mismatch");
        let nynx = cells / self.cap_dt.len();
        for (z, &cdt) in self.cap_dt.iter().enumerate() {
            let base = z * nynx;
            for i in base..base + nynx {
                self.p_eff[i] = scale * pow_[i] + cdt * self.t_prev[i];
            }
        }
    }
}

/// Run the closed DTM loop: `steps()` implicit-Euler steps over a cycling
/// window schedule, the controller scaling each step's power from the last
/// simulated peak temperature.  `power_of(window, last_peak_c, buf)` writes
/// the unscaled power grid for the given trace window (temperature is
/// passed so callers can couple leakage to the simulated state).
///
/// The plan state is reset to ambient first, so results depend only on the
/// arguments — deterministic for any worker count.
pub fn simulate_with<F>(
    plan: &mut TransientPlan,
    n_windows: usize,
    cfg: &TransientConfig,
    threshold_c: f64,
    it3d: usize,
    mut power_of: F,
) -> TransientStats
where
    F: FnMut(usize, f64, &mut [f64]),
{
    let steps = cfg.steps();
    let mut base = vec![0.0; plan.cells()];
    let mut last_c = cfg.ambient_c;
    let mut peak_c = cfg.ambient_c;
    let mut final_c = cfg.ambient_c;
    let mut time_over = 0.0;
    let mut scale_sum = 0.0;
    plan.reset();
    for k in 0..steps {
        let w = if n_windows == 0 { 0 } else { k % n_windows };
        let scale = cfg.controller.scale(k, last_c);
        scale_sum += scale;
        power_of(w, last_c, &mut base);
        let rise = plan.step_scaled(&base, scale, it3d);
        last_c = cfg.ambient_c + rise;
        peak_c = peak_c.max(last_c);
        final_c = last_c;
        if last_c > threshold_c {
            time_over += cfg.dt_s;
        }
    }
    TransientStats {
        peak_c,
        final_c,
        time_over_s: time_over,
        sustained_frac: if steps > 0 { scale_sum / steps as f64 } else { 1.0 },
    }
}

/// [`simulate_with`] over a fixed window trace: `pows` holds `n_windows`
/// concatenated power grids of `plan.cells()` each.
pub fn simulate(
    plan: &mut TransientPlan,
    pows: &[f64],
    n_windows: usize,
    cfg: &TransientConfig,
    threshold_c: f64,
    it3d: usize,
) -> TransientStats {
    let cells = plan.cells();
    assert!(n_windows > 0, "at least one trace window");
    assert_eq!(pows.len(), n_windows * cells, "pows must hold {n_windows} grids");
    simulate_with(plan, n_windows, cfg, threshold_c, it3d, |w, _t, buf| {
        buf.copy_from_slice(&pows[w * cells..(w + 1) * cells]);
    })
}

/// Batched scenario simulation fanned over `workers` threads: `pows` holds
/// `n` designs × `n_windows` window grids; each worker builds one plan for
/// its contiguous chunk.  Position-stable and bit-identical for any worker
/// count (mirrors [`super::plan::solve_peak_batch_par`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_batch_par(
    grid: &ThermalGrid,
    cap: &[f64],
    pows: &[f64],
    n: usize,
    n_windows: usize,
    cfg: &TransientConfig,
    threshold_c: f64,
    it3d: usize,
    workers: usize,
) -> Vec<TransientStats> {
    let cells = grid.z * grid.y * grid.x;
    let per_design = n_windows * cells;
    assert_eq!(pows.len(), n * per_design, "pows must hold {n} designs");
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let per = n.div_ceil(workers);
    let chunks: Vec<(usize, usize)> =
        (0..n).step_by(per).map(|lo| (lo, (lo + per).min(n))).collect();
    let parts = crate::util::threadpool::scope_map(chunks, workers, |(lo, hi)| {
        let mut plan = TransientPlan::new(grid, cap, cfg.dt_s);
        (lo..hi)
            .map(|i| {
                simulate(
                    &mut plan,
                    &pows[i * per_design..(i + 1) * per_design],
                    n_windows,
                    cfg,
                    threshold_c,
                    it3d,
                )
            })
            .collect::<Vec<_>>()
    });
    parts.into_iter().flatten().collect()
}

/// Dominant thermal time constant of a stack column [s]: total column heat
/// capacity over the sink-path conductance.  Drives the first-order RC
/// reduction used on the DSE score path.
pub fn stack_tau_s(stack: &LayerStack) -> f64 {
    stack.cap().iter().sum::<f64>() / stack.gdn()[0]
}

/// Score-path transient summary from the cheap RC reduction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheapTransient {
    /// Peak transient temperature rise over the horizon (throttle-aware).
    pub peak_rise: f64,
    /// Mean controller power scale (sustained-vs-burst throughput).
    pub sustained_frac: f64,
}

/// First-order RC transient over the Eq.(7) per-window peak rises: the
/// same implicit-Euler scheme and controller semantics as the full-grid
/// path, reduced to one state (`h' = (scale * rise - h) / tau`).  This is
/// what [`crate::opt::Problem`] applies per probe — a handful of scalar
/// operations, cheap enough for the score hot path.
pub fn cheap_transient(rises: &[f64], tau_s: f64, cfg: &TransientConfig) -> CheapTransient {
    assert!(!rises.is_empty(), "at least one window rise");
    assert!(tau_s > 0.0, "time constant must be positive");
    let steps = cfg.steps();
    let a = cfg.dt_s / tau_s;
    let mut h = 0.0f64;
    let mut peak = 0.0f64;
    let mut scale_sum = 0.0f64;
    for k in 0..steps {
        let r = rises[k % rises.len()];
        let scale = cfg.controller.scale(k, cfg.ambient_c + h);
        scale_sum += scale;
        // Implicit Euler on the scalar RC (same scheme as the grid path).
        h = (h + a * scale * r) / (1.0 + a);
        peak = peak.max(h);
    }
    CheapTransient {
        peak_rise: peak,
        sustained_frac: if steps > 0 { scale_sum / steps as f64 } else { 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::thermal::T_AMBIENT_C;

    fn small_plan(stack: &LayerStack, dt: f64) -> TransientPlan {
        TransientPlan::for_stack(stack, 4, 4, dt)
    }

    fn top_tier_power(stack: &LayerStack, ny: usize, nx: usize, scale: f64) -> Vec<f64> {
        let mut p = vec![0.0; stack.z() * ny * nx];
        let plane = ny * nx;
        let zl = stack.tier_layer(3);
        for i in 0..plane {
            p[zl * plane + i] = scale * (0.2 + 0.05 * (i % 3) as f64);
        }
        p
    }

    #[test]
    fn stepping_is_monotone_toward_steady_state_under_constant_power() {
        let stack = LayerStack::m3d();
        let mut plan = small_plan(&stack, 1.0e-3);
        let p = top_tier_power(&stack, 4, 4, 1.0);
        let mut prev = 0.0;
        for _ in 0..20 {
            let peak = plan.step_scaled(&p, 1.0, 120);
            assert!(peak >= prev - 1e-12, "warm-up must be monotone: {peak} < {prev}");
            prev = peak;
        }
        assert!(prev > 0.0);
    }

    #[test]
    fn zero_dt_horizon_disables_the_scenario() {
        let mut cfg = TransientConfig::default();
        assert!(cfg.enabled());
        cfg.horizon_s = 0.0;
        assert!(!cfg.enabled());
        assert_eq!(cfg.steps(), 0);
        let cfg2 = TransientConfig { dt_s: 0.0, ..TransientConfig::default() };
        assert!(!cfg2.enabled());
    }

    #[test]
    fn controller_scale_is_always_a_fraction() {
        let ctrls = [
            Controller::None,
            Controller::Throttle { trip_c: 85.0, relief: 0.7 },
            Controller::Throttle { trip_c: 85.0, relief: 1.7 }, // clamped
            Controller::SprintRest { sprint_steps: 3, rest_steps: 2, rest_scale: 0.5 },
            Controller::SprintRest { sprint_steps: 0, rest_steps: 0, rest_scale: -0.5 },
        ];
        for c in ctrls {
            for step in 0..16 {
                for t in [20.0, 84.9, 85.0, 120.0] {
                    let s = c.scale(step, t);
                    assert!((0.0..=1.0).contains(&s), "{c:?} step {step} t {t} -> {s}");
                }
            }
        }
    }

    #[test]
    fn sprint_rest_follows_the_duty_cycle() {
        let c = Controller::SprintRest { sprint_steps: 2, rest_steps: 1, rest_scale: 0.25 };
        let scales: Vec<f64> = (0..6).map(|k| c.scale(k, T_AMBIENT_C)).collect();
        assert_eq!(scales, vec![1.0, 1.0, 0.25, 1.0, 1.0, 0.25]);
    }

    #[test]
    fn throttle_relieves_hot_and_passes_cool() {
        let c = Controller::Throttle { trip_c: 85.0, relief: 0.6 };
        assert_eq!(c.scale(0, 84.9), 1.0);
        assert_eq!(c.scale(0, 85.0), 0.6);
        assert_eq!(c.scale(0, 200.0), 0.6);
    }

    #[test]
    fn simulate_reports_sustained_fraction_and_threshold_time() {
        let stack = LayerStack::m3d();
        let cfg = TransientConfig {
            horizon_s: 8.0e-3,
            dt_s: 1.0e-3,
            controller: Controller::SprintRest { sprint_steps: 1, rest_steps: 1, rest_scale: 0.5 },
            ambient_c: T_AMBIENT_C,
        };
        let mut plan = small_plan(&stack, cfg.dt_s);
        let p = top_tier_power(&stack, 4, 4, 1.0);
        let stats = simulate(&mut plan, &p, 1, &cfg, 1000.0, 120);
        assert!((stats.sustained_frac - 0.75).abs() < 1e-12);
        assert_eq!(stats.time_over_s, 0.0, "nothing exceeds a 1000 C threshold");
        assert!(stats.peak_c >= stats.final_c);
        assert!(stats.peak_c > T_AMBIENT_C);
        // Everything is over an ambient-level threshold after step 1.
        let mut plan2 = small_plan(&stack, cfg.dt_s);
        let hot = simulate(&mut plan2, &p, 1, &cfg, T_AMBIENT_C, 120);
        assert!(hot.time_over_s > 0.0);
        assert!(hot.time_over_s <= cfg.horizon_s + cfg.dt_s);
    }

    #[test]
    fn batch_par_matches_serial_for_any_worker_count() {
        let stack = LayerStack::tsv(true);
        let grid = ThermalGrid::new(stack.z(), 4, 4, GridParams::from_stack(&stack));
        let cap = stack.cap();
        let cfg = TransientConfig {
            horizon_s: 5.0e-3,
            dt_s: 1.0e-3,
            controller: Controller::Throttle { trip_c: 42.0, relief: 0.5 },
            ambient_c: T_AMBIENT_C,
        };
        let cells = grid.z * 16;
        let n = 3;
        let n_windows = 2;
        let pows: Vec<f64> = (0..n * n_windows * cells)
            .map(|i| ((i * 13) % 7) as f64 * 0.08)
            .collect();
        let serial = simulate_batch_par(&grid, &cap, &pows, n, n_windows, &cfg, 60.0, 60, 1);
        for workers in [2, 4] {
            let par = simulate_batch_par(&grid, &cap, &pows, n, n_windows, &cfg, 60.0, 60, workers);
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(par.iter()) {
                assert_eq!(a.peak_c.to_bits(), b.peak_c.to_bits(), "workers {workers}");
                assert_eq!(a.final_c.to_bits(), b.final_c.to_bits());
                assert_eq!(a.time_over_s.to_bits(), b.time_over_s.to_bits());
                assert_eq!(a.sustained_frac.to_bits(), b.sustained_frac.to_bits());
            }
        }
    }

    #[test]
    fn cheap_transient_peaks_below_the_steady_rise_and_throttle_helps() {
        let stack = LayerStack::m3d();
        let tau = stack_tau_s(&stack);
        assert!(tau > 0.0);
        let rises = [12.0, 30.0, 22.0, 8.0];
        let cfg = TransientConfig {
            horizon_s: 20.0 * tau,
            dt_s: tau / 4.0,
            controller: Controller::None,
            ambient_c: T_AMBIENT_C,
        };
        let free = cheap_transient(&rises, tau, &cfg);
        assert!(free.peak_rise > 0.0);
        assert!(free.peak_rise <= 30.0 + 1e-9, "cannot exceed the worst window rise");
        assert_eq!(free.sustained_frac, 1.0);

        let throttled_cfg = TransientConfig {
            controller: Controller::Throttle { trip_c: T_AMBIENT_C + 15.0, relief: 0.4 },
            ..cfg
        };
        let thr = cheap_transient(&rises, tau, &throttled_cfg);
        assert!(thr.peak_rise <= free.peak_rise + 1e-12);
        assert!(thr.sustained_frac < 1.0);
    }
}
