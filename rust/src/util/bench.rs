//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `rust/benches/*.rs` as a `harness = false`
//! binary; they use this module for warmup + repeated timing with
//! mean/min/max reporting.

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` runs; prints a line and
/// returns the mean seconds per iteration.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "bench {name:<40} {:>12}  (min {}, max {}, n={})",
        fmt_time(mean),
        fmt_time(min),
        fmt_time(max),
        samples.len()
    );
    mean
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Throughput line helper.
pub fn report_rate(name: &str, items: f64, seconds: f64) {
    println!("rate  {name:<40} {:>12.1} items/s", items / seconds.max(1e-12));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_mean() {
        let mut x = 0u64;
        let mean = bench("noop-ish", 1, 3, || {
            x = x.wrapping_add(1);
        });
        assert!(mean >= 0.0);
        assert_eq!(x, 4);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
