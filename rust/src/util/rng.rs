//! Deterministic PRNG — SplitMix64 seeding + xoshiro256** core.
//!
//! The offline image carries no `rand` crate, so the whole repository draws
//! randomness from this module.  Every experiment takes an explicit `u64`
//! seed so figures are exactly re-generable.

/// xoshiro256** by Blackman & Vigna (public domain reference
/// implementation), seeded via SplitMix64 as the authors recommend.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Second Box-Muller deviate banked by [`Rng::normal`]; each uniform
    /// pair yields two independent normals, so discarding the sine branch
    /// (the previous behaviour) doubled the transcendental cost of every
    /// normal-heavy consumer (Monte Carlo variation sampling above all).
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-worker / per-window rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) — unbiased via rejection (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.  Each uniform pair produces two
    /// independent deviates; the sine branch is banked and returned by the
    /// next call, so consecutive calls cost one `ln`/`sqrt` pair per *two*
    /// normals instead of per one.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / stddev.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::seed_from_u64(9);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_pairs_cost_one_uniform_pair() {
        // Two consecutive normals consume exactly two uniforms (the second
        // deviate is served from the banked sine branch), so the underlying
        // stream stays aligned with a control that drew two f64s.
        let mut a = Rng::seed_from_u64(33);
        let mut b = Rng::seed_from_u64(33);
        let (z0, z1) = (a.normal(), a.normal());
        assert!(z0.is_finite() && z1.is_finite() && z0 != z1);
        let _ = (b.f64(), b.f64());
        for _ in 0..8 {
            assert_eq!(a.next_u64(), b.next_u64(), "spare banking desynced the stream");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::seed_from_u64(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::seed_from_u64(21);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
