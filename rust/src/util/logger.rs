//! Leveled stderr logger with monotonic timestamps (the `log` facade is in
//! the cache but a full env_logger is not; this keeps output self-contained).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // info

/// Log verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Progress reporting (the default).
    Info = 2,
    /// Verbose diagnostics.
    Debug = 3,
}

/// Set the global log level (e.g. from `--log debug`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name; `trace` is accepted as an alias for `debug` (this
/// logger has no finer tier).  An unknown name falls back to Info, but
/// says so once instead of silently eating the typo (`--log inf`).
pub fn level_from_str(s: &str) -> Level {
    match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" | "trace" => Level::Debug,
        other => {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                emit(
                    Level::Warn,
                    format_args!(
                        "unknown log level '{other}' (expected error|warn|info|debug|trace); using info"
                    ),
                );
            });
            Level::Info
        }
    }
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn t0() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Emit one log line (used by the macros below).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let dt = t0().elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:9.3}s {tag}] {args}", dt.as_secs_f64());
}

#[macro_export]
/// Log at error level (printf-style).
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Error, format_args!($($arg)*)) } }
#[macro_export]
/// Log at warn level (printf-style).
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Warn, format_args!($($arg)*)) } }
#[macro_export]
/// Log at info level (printf-style).
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Info, format_args!($($arg)*)) } }
#[macro_export]
/// Log at debug level (printf-style).
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::emit($crate::util::logger::Level::Debug, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn level_parsing() {
        assert_eq!(level_from_str("error"), Level::Error);
        assert_eq!(level_from_str("warn"), Level::Warn);
        assert_eq!(level_from_str("info"), Level::Info);
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("trace"), Level::Debug, "trace aliases debug");
        // Unknown names warn once (a Once, not asserted here) and fall
        // back to Info rather than silently changing verbosity.
        assert_eq!(level_from_str("nonsense"), Level::Info);
        assert_eq!(level_from_str("nonsense"), Level::Info);
    }
}
