//! Minimal JSON value model, parser and writer (serde is unavailable).
//!
//! Used for: trace files, campaign reports, bench output, and checking
//! `artifacts/meta.json` against the compiled-in tensor contract.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Numbers are f64 (sufficient for our reports/traces).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from an iterator.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Boolean value.
    pub fn bool(b: bool) -> Json {
        Json::Bool(b)
    }

    /// Field access for objects; None otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Index access for arrays; None otherwise.
    pub fn at(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Exact u64 value, if this is a non-negative integral number that f64
    /// represents losslessly (<= 2^53).  Strict by design: the run-store
    /// loaders treat fractional/negative counters and versions as
    /// corruption, not as values to round.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; `write!("{x}")` would emit
                    // `NaN`/`inf`, which `parse` itself rejects.  Serialize
                    // non-finite numbers as null so every document this
                    // writer produces is parseable.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.  Errors carry a byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("hem3d")),
            ("tiles", Json::num(64.0)),
            ("ratio", Json::num(0.77)),
            ("tags", Json::arr([Json::str("m3d"), Json::str("noc")])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("nil", Json::Null)])),
        ]);
        let parsed = parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
        let parsed2 = parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed2, j);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#"{"s":"a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn parses_numbers() {
        let v = parse("[-1.5e3, 0, 42, 0.125]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), -1500.0);
        assert_eq!(arr[2].as_usize().unwrap(), 42);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::arr([Json::num(x)]);
            assert_eq!(j.to_string(), "[null]");
            // What the writer emits must be parseable by our own parser.
            assert_eq!(parse(&j.to_string()).unwrap(), Json::arr([Json::Null]));
        }
    }

    #[test]
    fn prop_number_roundtrip_is_exact_or_null() {
        // Finite numbers survive serialize -> parse bit-exactly (Rust's
        // `{}` float formatting is shortest-round-trip); non-finite ones
        // degrade to null but never to an unparseable document.
        crate::util::prop::check("json number roundtrip", 400, |g| {
            let x = match g.rng.range(0, 6) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => (g.f64(-1.0, 1.0)) * 1e300,
                4 => (g.f64(-1.0, 1.0)) * 1e-300,
                _ => g.f64(-1e9, 1e9),
            };
            let doc = Json::obj(vec![("x", Json::num(x))]);
            let parsed = parse(&doc.to_string())
                .map_err(|e| format!("writer produced unparseable JSON for {x}: {e}"))?;
            match parsed.get("x") {
                Some(Json::Null) if !x.is_finite() => Ok(()),
                Some(Json::Num(y)) if x.is_finite() && x.to_bits() == y.to_bits() => Ok(()),
                other => Err(format!("{x} round-tripped to {other:?}")),
            }
        });
    }

    #[test]
    fn prop_document_roundtrip_is_byte_identical() {
        // serialize -> parse -> re-serialize must be byte-identical for
        // finite documents (the run-store resume contract relies on this).
        crate::util::prop::check("json document roundtrip", 200, |g| {
            let n = g.int(0, 8);
            let doc = Json::obj(vec![
                ("name", Json::str("leg")),
                ("flag", Json::bool(g.rng.chance(0.5))),
                ("xs", Json::arr((0..n).map(|_| Json::num(g.f64(-1e6, 1e6))))),
                (
                    "nested",
                    Json::obj(vec![("k", Json::num(g.int(0, 1000) as f64)), ("nil", Json::Null)]),
                ),
            ]);
            let s1 = doc.to_string();
            let reparsed = parse(&s1).map_err(|e| e.to_string())?;
            let s2 = reparsed.to_string();
            if s1 != s2 {
                return Err(format!("reserialization differs:\n{s1}\n{s2}"));
            }
            let p1 = doc.to_pretty();
            let p2 = parse(&p1).map_err(|e| e.to_string())?.to_pretty();
            if p1 != p2 {
                return Err("pretty reserialization differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn meta_json_shape_check() {
        // Mirrors the artifacts/meta.json structure the runtime validates.
        let doc = r#"{"moo_eval": {"batch": 16, "tiles": 64}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("moo_eval").unwrap().get("batch").unwrap().as_usize(), Some(16));
    }
}
