//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `hem3d <command> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments after the command.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--key` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    ///
    /// # Examples
    ///
    /// ```
    /// use hem3d::util::cli::Args;
    ///
    /// let argv = ["sim", "--pattern", "hotspot", "--vcs=4", "--vc-depth", "2"];
    /// let args = Args::parse(argv.iter().map(|s| s.to_string()));
    /// assert_eq!(args.command.as_deref(), Some("sim"));
    /// assert_eq!(args.opt("pattern"), Some("hotspot"));
    /// assert_eq!(args.usize_or("vcs", 1), 4);
    /// assert_eq!(args.usize_or("vc-depth", 1), 2);
    /// ```
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw option value, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Whether a boolean flag is set (`--key`, `--key=true`...).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parsed usize option with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Parsed u64 option with a default.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Parsed f64 option with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parses_command_and_options() {
        // Note: a bare `--flag` must come last or use `--flag=true`, since
        // `--flag value` binds the value (documented quirk below).
        let a = parse("optimize trace.json --tech m3d --iters=50 --verbose");
        assert_eq!(a.command.as_deref(), Some("optimize"));
        assert_eq!(a.opt("tech"), Some("m3d"));
        assert_eq!(a.usize_or("iters", 0), 50);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["trace.json"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("sim");
        assert_eq!(a.usize_or("iters", 7), 7);
        assert_eq!(a.f64_or("alpha", 0.5), 0.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bare_flag_before_positional_consumes_next_token() {
        // Documented quirk: `--flag value` binds value to flag.
        let a = parse("run --check out.json");
        assert_eq!(a.opt("check"), Some("out.json"));
    }
}
