//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! `check` runs a predicate over `n` seeded random cases; on failure it
//! retries the failing seed with progressively "smaller" generator budgets
//! (a crude shrink) and reports the smallest failing seed/budget pair so the
//! failure is reproducible with `case()`.

use super::rng::Rng;

/// Generation budget handed to each case: use `size` to bound collection
/// lengths / value magnitudes so shrinking produces simpler cases.
pub struct Gen {
    /// Seeded randomness for the case.
    pub rng: Rng,
    /// Generation budget (bounds collection sizes / magnitudes).
    pub size: usize,
}

impl Gen {
    /// Random usize in [lo, hi] inclusive, additionally capped by budget.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        self.rng.range(lo, hi)
    }

    /// Random f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Random vec of given length via element generator.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut xs: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut xs);
        xs
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    /// Seed reproducing the failure.
    pub seed: u64,
    /// Generation budget (bounds collection sizes / magnitudes).
    pub size: usize,
    /// The property's failure message.
    pub message: String,
}

/// Run `prop` over `cases` random cases.  Panics (with the reproducing seed)
/// on the first failure after shrinking the budget.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    if let Some(f) = check_quiet(cases, &prop) {
        panic!(
            "property '{name}' failed: {} \n  reproduce: seed={} size={}",
            f.message, f.seed, f.size
        );
    }
}

/// Like `check` but returns the failure instead of panicking (for testing
/// the framework itself).
pub fn check_quiet(
    cases: u64,
    prop: &impl Fn(&mut Gen) -> Result<(), String>,
) -> Option<Failure> {
    for case_idx in 0..cases {
        let seed = 0x5eed_0000u64.wrapping_add(case_idx.wrapping_mul(0x9e37_79b9));
        let size = 4 + (case_idx as usize * 7) % 60;
        if let Err(msg) = run_case(seed, size, prop) {
            // Shrink: re-run the same seed with smaller budgets.
            let mut best = Failure { seed, size, message: msg };
            let mut s = size;
            while s > 1 {
                s /= 2;
                match run_case(seed, s, prop) {
                    Err(msg) => best = Failure { seed, size: s, message: msg },
                    Ok(()) => break,
                }
            }
            return Some(best);
        }
    }
    None
}

/// Run a single reproducible case.
pub fn run_case(
    seed: u64,
    size: usize,
    prop: &impl Fn(&mut Gen) -> Result<(), String>,
) -> Result<(), String> {
    let mut gen = Gen { rng: Rng::seed_from_u64(seed), size };
    prop(&mut gen)
}

/// Assert helper producing property-friendly errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |g| {
            let a = g.f64(-10.0, 10.0);
            let b = g.f64(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let f = check_quiet(100, &|g: &mut Gen| {
            let n = g.int(0, 100);
            if n < 10 {
                Ok(())
            } else {
                Err(format!("n={n} too big"))
            }
        });
        let f = f.expect("property should fail");
        // Shrinking should have reduced the budget.
        assert!(f.size <= 16, "expected shrunk size, got {}", f.size);
    }

    #[test]
    fn cases_are_reproducible() {
        let run = |seed| {
            let mut g = Gen { rng: Rng::seed_from_u64(seed), size: 10 };
            (g.int(0, 100), g.f64(0.0, 1.0))
        };
        assert_eq!(run(42), run(42));
    }

    #[test]
    fn permutation_is_valid() {
        let mut g = Gen { rng: Rng::seed_from_u64(3), size: 8 };
        let p = g.permutation(20);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..20).collect::<Vec<_>>());
    }
}
