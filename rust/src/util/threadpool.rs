//! Parallel-map entry points (rayon/tokio are unavailable offline).
//!
//! [`scope_map`] is the historical API the coordinator uses to fan
//! candidate evaluation and per-benchmark campaign legs across cores; it
//! is now a thin wrapper over the work-stealing scheduler in
//! [`crate::util::scheduler`] so every existing call site upgrades at
//! once (stealable batches, cross-leg backfill, labeled panic
//! propagation).  The original shared-queue implementation is kept as
//! [`scope_map_shared_queue`] — it is the *static* baseline the
//! `scheduler` bench leg races the work-stealing pool against, and a
//! reference for what the old semantics were.

use crate::util::scheduler;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Parallel map: applies `f` to each item on up to `workers` OS threads,
/// returning results in input order (determinism by reduction order, not
/// schedule).  Falls back to a serial loop for `workers <= 1` or tiny
/// inputs.  Delegates to the work-stealing scheduler: when called from
/// inside an enclosing pool the batch becomes stealable by idle workers
/// instead of splitting the thread budget.
pub fn scope_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    scheduler::ws_map(items, workers, f)
}

/// The pre-scheduler static map: one shared `Mutex<Vec>` queue drained by
/// `workers` threads, results funneled through a channel.  Balances a
/// single flat batch but cannot backfill across nested fan-outs — kept
/// solely as the baseline for the `scheduler` bench leg and as executable
/// documentation of the old behaviour.  New call sites should use
/// [`scope_map`].
pub fn scope_map_shared_queue<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, T)>>> =
        Arc::new(Mutex::new(items.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let nw = workers.min(n);
    thread::scope(|s| {
        for _ in 0..nw {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, x)) => {
                        if tx.send((i, f(x))).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker dropped result")).collect()
    })
}

/// Suggested worker count: respects `HEM3D_WORKERS` (documented in the
/// README), defaults to available parallelism.  `HEM3D_WORKERS=0` is a
/// configuration error someone will eventually make in a CI matrix, so it
/// clamps to 1 (serial) explicitly rather than feeding 0 into pool math.
pub fn default_workers() -> usize {
    if let Ok(s) = std::env::var("HEM3D_WORKERS") {
        if let Ok(n) = s.parse::<usize>() {
            if n == 0 {
                return 1;
            }
            return n;
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scope_map(items, 4, |x| x * 3);
        assert_eq!(out, (0..100).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_fallback_matches() {
        let items: Vec<usize> = (0..10).collect();
        let a = scope_map(items.clone(), 1, |x| x + 1);
        let b = scope_map(items, 8, |x| x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn handles_empty_input() {
        let out: Vec<usize> = scope_map(Vec::<usize>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = scope_map(vec![1, 2], 16, |x| x * x);
        assert_eq!(out, vec![1, 4]);
    }

    #[test]
    fn shared_queue_baseline_matches_scheduler() {
        let items: Vec<usize> = (0..64).collect();
        let a = scope_map_shared_queue(items.clone(), 4, |x| x * 7 + 3);
        let b = scope_map(items, 4, |x| x * 7 + 3);
        assert_eq!(a, b);
    }
}
