//! Fixed-size scoped work pool (rayon/tokio are unavailable offline).
//!
//! The coordinator uses this to fan candidate evaluation and per-benchmark
//! campaign legs across cores.  Work items are boxed closures pushed to a
//! shared queue; `scope_map` provides the common "parallel map" shape with
//! ordered results.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Parallel map: applies `f` to each item on up to `workers` OS threads,
/// returning results in input order.  Falls back to a serial loop for
/// `workers <= 1` or tiny inputs (avoids spawn overhead on 1-core hosts).
pub fn scope_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, T)>>> =
        Arc::new(Mutex::new(items.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let nw = workers.min(n);
    thread::scope(|s| {
        for _ in 0..nw {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let f = &f;
            s.spawn(move || loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, x)) => {
                        if tx.send((i, f(x))).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker dropped result")).collect()
    })
}

/// Suggested worker count: respects HEM3D_WORKERS, defaults to available
/// parallelism.
pub fn default_workers() -> usize {
    if let Ok(s) = std::env::var("HEM3D_WORKERS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scope_map(items, 4, |x| x * 3);
        assert_eq!(out, (0..100).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_fallback_matches() {
        let items: Vec<usize> = (0..10).collect();
        let a = scope_map(items.clone(), 1, |x| x + 1);
        let b = scope_map(items, 8, |x| x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn handles_empty_input() {
        let out: Vec<usize> = scope_map(Vec::<usize>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let out = scope_map(vec![1, 2], 16, |x| x * x);
        assert_eq!(out, vec![1, 4]);
    }
}
