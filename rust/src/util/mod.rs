//! Infrastructure substrates.
//!
//! The build image is offline and only caches the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, serde, clap, rayon,
//! criterion, proptest) are unavailable; this module provides the minimal
//! replacements the rest of the system needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod scheduler;
pub mod stats;
pub mod threadpool;

pub use rng::Rng;
