//! Work-stealing evaluation scheduler (DESIGN.md §16).
//!
//! The static `scope_map` shared-queue map (PR 1) balances *one flat batch*
//! well, but nested fan-outs — a figure assembly mapping over benchmark
//! legs, each leg mapping over Monte Carlo samples — had to *split* the
//! worker budget up front: with 8 workers and 4 legs, a robust leg whose
//! MC fan-out costs ~30x a nominal eval ground away on its 2-thread share
//! while the other 6 workers sat idle.  This module replaces that with a
//! single shared pool per top-level fan-out:
//!
//! * each worker owns a Chase-Lev-style deque (lock-free owner push/pop at
//!   the bottom, CAS steal at the top; growable ring buffer, no external
//!   crates);
//! * a *nested* `ws_map` call from inside a pool worker does not spawn
//!   threads: it pushes its jobs onto the calling worker's own deque and
//!   executes them LIFO, while idle workers steal them FIFO from the other
//!   end — so a long robust/fault MC leg is automatically backfilled by
//!   every worker that ran out of its own legs (cross-leg pipelining);
//! * while waiting for its batch to drain, a nested caller *helps*: it
//!   executes any job it can pop or steal, so the pool never idles a
//!   thread that still has runnable work anywhere.
//!
//! # Determinism: by reduction order, not by schedule
//!
//! Which worker executes a job, and in which order jobs interleave, is
//! nondeterministic.  Results are not: every job writes its result into an
//! index-addressed slot of its batch, and the batch returns `Vec<R>` in
//! input order — exactly the contract the static `scope_map` had.  As long
//! as the mapped function is pure (the standing §6 contract), every
//! statistic downstream is bit-identical for any worker count and any
//! steal schedule (`tests/parallel_determinism.rs`, `tests/variation.rs`,
//! `tests/faults.rs`, `tests/ladder.rs`, `tests/scheduler.rs`).
//!
//! # Batch granularity
//!
//! A job should cost well over the ~1 us scheduling overhead (push + steal
//! CAS + slot write).  Call sites follow two rules: *per-item* jobs where
//! one item is already expensive (candidate scoring ~ms, MC samples ~ms),
//! and *contiguous chunks* where items are cheap (`solve_peak_batch_par`
//! chunks designs so each job amortises one plan build).  Nothing here
//! re-chunks behind the caller's back — granularity is the call site's
//! decision, the scheduler only balances it.
//!
//! # Telemetry
//!
//! Every pool counts per-worker executed tasks, steals and idle
//! nanoseconds ([`PoolReport`]), and the same counters accumulate
//! process-wide ([`stats`]) so `hem3d bench --json` can report scheduler
//! behaviour for any leg (the `scheduler` bench leg asserts steals
//! actually happen on a skewed workload).
//!
//! # Panics
//!
//! A panicking job does not poison the pool: the panic is caught, the
//! batch drains fully, and the batch initiator re-raises the panic naming
//! the batch label and the job index (`"variation-mc-sample[17]
//! panicked: ..."`), so a dying eval names the design/sample that died.
//! Nested batches chain naturally: the leg job that observed the sample
//! panic re-panics, and the outer batch names the leg on top.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Chase-Lev deque
// ---------------------------------------------------------------------------

/// Result of a steal attempt on a [`Deque`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Steal {
    /// The deque looked empty.
    Empty,
    /// Lost a race with the owner or another thief; worth re-probing.
    Retry,
    /// Stole the oldest element.
    Data(usize),
}

/// Growable ring buffer of `usize` slots.  Cells are atomics so a stale
/// thief read after the owner wraps or grows is a *defined* read of a
/// stale value — which the subsequent `top` CAS then rejects.
struct Buf {
    mask: usize,
    data: Box<[AtomicUsize]>,
}

impl Buf {
    fn new(cap: usize) -> Box<Buf> {
        debug_assert!(cap.is_power_of_two());
        Box::new(Buf {
            mask: cap - 1,
            data: (0..cap).map(|_| AtomicUsize::new(0)).collect(),
        })
    }

    #[inline]
    fn get(&self, i: isize) -> usize {
        self.data[(i as usize) & self.mask].load(Ordering::Relaxed)
    }

    #[inline]
    fn put(&self, i: isize, v: usize) {
        self.data[(i as usize) & self.mask].store(v, Ordering::Relaxed);
    }
}

/// Chase-Lev work-stealing deque of `usize` values (the pool stores raw
/// job pointers in it; the tests store plain payloads).
///
/// Single logical owner: exactly one thread may call [`Deque::push`] /
/// [`Deque::pop`] at a time (the worker that owns it); any number of
/// threads may [`Deque::steal`] concurrently.  Violating the single-owner
/// rule cannot corrupt memory (all slots are atomics, retired buffers live
/// until drop) but loses the LIFO/FIFO guarantees.
///
/// The orderings follow Lê/Pop/Cohen/Nardelli, "Correct and Efficient
/// Work-Stealing for Weak Memory Models" (PPoPP'13): `push` publishes with
/// a release fence, `pop` reserves the bottom slot and then synchronises
/// with thieves through a SeqCst fence + `top` CAS on the last element,
/// `steal` CASes `top` SeqCst so at most one consumer wins each index.
/// Grown-out buffers are retired, not freed, until the deque drops, so a
/// thief holding a stale buffer pointer only ever reads stale *values*.
pub struct Deque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<Buf>,
    retired: Mutex<Vec<*mut Buf>>,
}

// Raw buffer pointers are shared across threads by design; all access is
// through atomics and retired buffers outlive every reader.
unsafe impl Send for Deque {}
unsafe impl Sync for Deque {}

impl Default for Deque {
    fn default() -> Self {
        Deque::with_capacity(64)
    }
}

impl Deque {
    /// Deque with an initial ring capacity (rounded up to a power of two).
    /// Pushing past capacity grows the ring (doubling); capacity only
    /// bounds allocation, never correctness.
    pub fn with_capacity(cap: usize) -> Deque {
        let cap = cap.next_power_of_two().max(2);
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Box::into_raw(Buf::new(cap))),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Number of elements currently visible (approximate under races).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Whether the deque looks empty (approximate under races).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner-only: push `v` at the bottom.
    pub fn push(&self, v: usize) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        if b - t > buf.mask as isize {
            buf = self.grow(t, b);
        }
        buf.put(b, v);
        std::sync::atomic::fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only: pop the most recently pushed element (LIFO).
    pub fn pop(&self) -> Option<usize> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        self.bottom.store(b, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let v = buf.get(b);
            if t == b {
                // Last element: race the thieves for it.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                return won.then_some(v);
            }
            Some(v)
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief: steal the oldest element (FIFO end).  Safe from any thread.
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let buf = unsafe { &*self.buf.load(Ordering::Acquire) };
            let v = buf.get(t);
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_err()
            {
                return Steal::Retry;
            }
            Steal::Data(v)
        } else {
            Steal::Empty
        }
    }

    /// Owner-only (called from `push`): double the ring, copying the live
    /// range `t..b`, and retire the old buffer until drop.
    fn grow(&self, t: isize, b: isize) -> &Buf {
        let old_ptr = self.buf.load(Ordering::Relaxed);
        let old = unsafe { &*old_ptr };
        let new = Buf::new((old.mask + 1) * 2);
        for i in t..b {
            new.put(i, old.get(i));
        }
        let new_ptr = Box::into_raw(new);
        self.buf.store(new_ptr, Ordering::Release);
        self.retired.lock().unwrap().push(old_ptr);
        unsafe { &*new_ptr }
    }
}

impl Drop for Deque {
    fn drop(&mut self) {
        unsafe {
            drop(Box::from_raw(self.buf.load(Ordering::Relaxed)));
            for p in self.retired.get_mut().unwrap().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------------

/// Process-wide cumulative scheduler counters (see [`stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Jobs executed through pools (serial fallbacks are not counted).
    pub tasks: u64,
    /// Successful steals (a job executed by a worker that did not own it).
    pub steals: u64,
    /// Nanoseconds workers spent finding no runnable job anywhere.
    pub idle_ns: u64,
    /// Top-level pools created.
    pub pools: u64,
    /// Stealable batches submitted (root + nested).
    pub batches: u64,
}

static G_TASKS: AtomicU64 = AtomicU64::new(0);
static G_STEALS: AtomicU64 = AtomicU64::new(0);
static G_IDLE_NS: AtomicU64 = AtomicU64::new(0);
static G_POOLS: AtomicU64 = AtomicU64::new(0);
static G_BATCHES: AtomicU64 = AtomicU64::new(0);

/// Cumulative scheduler counters since process start.  Monotone: sample
/// before and after a region and subtract to attribute work to it (what
/// the `scheduler` bench leg does).
pub fn stats() -> SchedStats {
    SchedStats {
        tasks: G_TASKS.load(Ordering::Relaxed),
        steals: G_STEALS.load(Ordering::Relaxed),
        idle_ns: G_IDLE_NS.load(Ordering::Relaxed),
        pools: G_POOLS.load(Ordering::Relaxed),
        batches: G_BATCHES.load(Ordering::Relaxed),
    }
}

/// Per-worker counters of one pool run (returned by [`ws_map_pool_report`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerReport {
    /// Jobs this worker executed (own + stolen).
    pub tasks: u64,
    /// Jobs this worker stole from another worker's deque.
    pub steals: u64,
    /// Nanoseconds this worker spent with no runnable job anywhere.
    pub idle_ns: u64,
}

/// Aggregated telemetry of one top-level pool run.
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    /// One entry per worker (index = worker id; worker 0 is the caller).
    pub per_worker: Vec<WorkerReport>,
}

impl PoolReport {
    /// Total jobs executed across workers.
    pub fn tasks(&self) -> u64 {
        self.per_worker.iter().map(|w| w.tasks).sum()
    }

    /// Total successful steals across workers.
    pub fn steals(&self) -> u64 {
        self.per_worker.iter().map(|w| w.steals).sum()
    }

    /// Total idle nanoseconds across workers.
    pub fn idle_ns(&self) -> u64 {
        self.per_worker.iter().map(|w| w.idle_ns).sum()
    }
}

struct WorkerCounters {
    tasks: AtomicU64,
    steals: AtomicU64,
    idle_ns: AtomicU64,
}

// ---------------------------------------------------------------------------
// Jobs and batches
// ---------------------------------------------------------------------------

/// Type-erased unit of work.  `run(ctx, index)` executes item `index` of
/// the batch behind `ctx`.  Job values live in a `Vec` owned by the stack
/// frame that submitted the batch; that frame only returns after the
/// batch's `done` counter reaches its length, and a job is removed from a
/// deque exactly once before it runs, so no deque ever holds a pointer to
/// a dead frame.
#[derive(Clone, Copy)]
struct Job {
    run: unsafe fn(*const (), usize),
    ctx: *const (),
    index: usize,
}

/// The shared, type-erased part of a batch: completion count and the first
/// recorded panic.
struct BatchHeader {
    label: &'static str,
    done: AtomicUsize,
    panic: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>>,
}

/// One submitted map batch: items in, slots out, shared header.
struct Batch<'f, T, R, F> {
    f: &'f F,
    items: Vec<std::cell::UnsafeCell<Option<T>>>,
    out: Vec<std::cell::UnsafeCell<Option<R>>>,
    header: BatchHeader,
}

impl<'f, T, R, F: Fn(T) -> R> Batch<'f, T, R, F> {
    fn new(label: &'static str, items: Vec<T>, f: &'f F) -> Self {
        let n = items.len();
        Batch {
            f,
            items: items.into_iter().map(|x| std::cell::UnsafeCell::new(Some(x))).collect(),
            out: (0..n).map(|_| std::cell::UnsafeCell::new(None)).collect(),
            header: BatchHeader {
                label,
                done: AtomicUsize::new(0),
                panic: Mutex::new(None),
            },
        }
    }

    fn jobs(&self) -> Vec<Job> {
        (0..self.items.len())
            .map(|i| Job {
                run: run_one::<T, R, F>,
                ctx: self as *const Self as *const (),
                index: i,
            })
            .collect()
    }

    /// Collect results after `done == n`; re-raises a recorded panic with
    /// the batch label and job index attached.
    fn finish(self) -> Vec<R> {
        debug_assert_eq!(self.header.done.load(Ordering::Acquire), self.out.len());
        if let Some((index, payload)) = self.header.panic.into_inner().unwrap() {
            panic!("{}[{index}] panicked: {}", self.header.label, panic_message(&payload));
        }
        self.out
            .into_iter()
            .map(|c| c.into_inner().expect("scheduler job left no result"))
            .collect()
    }
}

/// Best-effort human message from a panic payload.
fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Execute item `index` of the batch behind `ctx`.  Called exactly once
/// per (batch, index): the item is taken, the result written to its slot,
/// and only then is `done` published (release) so the waiter's acquire
/// load of `done` also acquires the slot write.
unsafe fn run_one<T, R, F: Fn(T) -> R>(ctx: *const (), index: usize) {
    let b = &*(ctx as *const Batch<'_, T, R, F>);
    let item = (*b.items[index].get()).take().expect("scheduler job executed twice");
    match catch_unwind(AssertUnwindSafe(|| (b.f)(item))) {
        Ok(v) => *b.out[index].get() = Some(v),
        Err(p) => {
            let mut slot = b.header.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some((index, p));
            }
        }
    }
    b.header.done.fetch_add(1, Ordering::Release);
}

// ---------------------------------------------------------------------------
// Pool
// ---------------------------------------------------------------------------

/// Shared state of one top-level pool: the worker deques, per-worker
/// counters, and the shutdown latch the initiator flips once the root
/// batch has drained.
struct PoolCore {
    deques: Box<[Deque]>,
    counters: Box<[WorkerCounters]>,
    shutdown: AtomicBool,
}

impl PoolCore {
    fn new(workers: usize) -> PoolCore {
        PoolCore {
            deques: (0..workers).map(|_| Deque::default()).collect(),
            counters: (0..workers)
                .map(|_| WorkerCounters {
                    tasks: AtomicU64::new(0),
                    steals: AtomicU64::new(0),
                    idle_ns: AtomicU64::new(0),
                })
                .collect(),
            shutdown: AtomicBool::new(false),
        }
    }
}

thread_local! {
    /// `(worker index, pool)` while this thread runs inside a pool.  The
    /// raw pointer is valid for exactly the span it is set: workers clear
    /// it before their `thread::scope` closes over the pool's frame.
    static WORKER: Cell<Option<(usize, *const PoolCore)>> = const { Cell::new(None) };
}

/// Worker index of the pool the current thread is running inside, if any
/// (`Some(0)` for the initiating thread while it drives a pool).  Used by
/// the telemetry span recorder to annotate trace lanes; `None` outside any
/// pool.
pub fn current_worker() -> Option<usize> {
    WORKER.with(|w| w.get().map(|(me, _)| me))
}

/// One full sweep over the other workers' deques; `Retry` re-probes the
/// same victim a few times before moving on.
fn steal_any(pool: &PoolCore, me: usize) -> Option<Job> {
    let n = pool.deques.len();
    for k in 1..n {
        let victim = (me + k) % n;
        let mut retries = 0;
        loop {
            match pool.deques[victim].steal() {
                Steal::Data(p) => return Some(unsafe { *(p as *const Job) }),
                Steal::Empty => break,
                Steal::Retry => {
                    retries += 1;
                    if retries > 8 {
                        break;
                    }
                    std::hint::spin_loop();
                }
            }
        }
    }
    None
}

/// Execute one job, attributing it to worker `me`.
#[inline]
fn execute(pool: &PoolCore, me: usize, job: Job, stolen: bool) {
    pool.counters[me].tasks.fetch_add(1, Ordering::Relaxed);
    G_TASKS.fetch_add(1, Ordering::Relaxed);
    if stolen {
        pool.counters[me].steals.fetch_add(1, Ordering::Relaxed);
        G_STEALS.fetch_add(1, Ordering::Relaxed);
    }
    unsafe { (job.run)(job.ctx, job.index) };
}

/// Account an idle span that just ended (or is ending at exit).
fn flush_idle(pool: &PoolCore, me: usize, idle_since: &mut Option<Instant>) {
    if let Some(t0) = idle_since.take() {
        let ns = t0.elapsed().as_nanos() as u64;
        pool.counters[me].idle_ns.fetch_add(ns, Ordering::Relaxed);
        G_IDLE_NS.fetch_add(ns, Ordering::Relaxed);
    }
}

/// Worker main loop.  `root_done` is `Some((counter, total))` only for
/// worker 0 (the pool initiator), which exits once the root batch drains
/// and then flips the shutdown latch for everyone else.
fn worker_loop(pool: &PoolCore, me: usize, root_done: Option<(&AtomicUsize, usize)>) {
    WORKER.with(|w| w.set(Some((me, pool as *const PoolCore))));
    let mut idle_since: Option<Instant> = None;
    loop {
        if let Some((done, total)) = root_done {
            if done.load(Ordering::Acquire) >= total {
                break;
            }
        }
        if let Some(p) = pool.deques[me].pop() {
            flush_idle(pool, me, &mut idle_since);
            execute(pool, me, unsafe { *(p as *const Job) }, false);
        } else if let Some(job) = steal_any(pool, me) {
            flush_idle(pool, me, &mut idle_since);
            execute(pool, me, job, true);
        } else {
            if root_done.is_none() && pool.shutdown.load(Ordering::Acquire) {
                break;
            }
            if idle_since.is_none() {
                idle_since = Some(Instant::now());
            }
            std::thread::yield_now();
        }
    }
    flush_idle(pool, me, &mut idle_since);
    WORKER.with(|w| w.set(None));
}

/// Run a batch from *inside* a pool worker: push the jobs on the caller's
/// own deque (stealable by everyone else), then execute/help until the
/// batch drains.  While waiting it runs *any* runnable job — including
/// jobs of other legs — which is what backfills idle workers and keeps
/// the caller busy instead of blocked.
fn run_nested<T, R, F>(
    pool: &PoolCore,
    me: usize,
    label: &'static str,
    items: Vec<T>,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let batch = Batch::new(label, items, &f);
    let jobs = batch.jobs();
    for job in &jobs {
        pool.deques[me].push(job as *const Job as usize);
    }
    G_BATCHES.fetch_add(1, Ordering::Relaxed);
    let mut idle_since: Option<Instant> = None;
    while batch.header.done.load(Ordering::Acquire) < n {
        if let Some(p) = pool.deques[me].pop() {
            flush_idle(pool, me, &mut idle_since);
            execute(pool, me, unsafe { *(p as *const Job) }, false);
        } else if let Some(job) = steal_any(pool, me) {
            flush_idle(pool, me, &mut idle_since);
            execute(pool, me, job, true);
        } else {
            // Own jobs stolen and still in flight elsewhere; nothing else
            // runnable right now.
            if idle_since.is_none() {
                idle_since = Some(Instant::now());
            }
            std::thread::yield_now();
        }
    }
    flush_idle(pool, me, &mut idle_since);
    drop(jobs);
    batch.finish()
}

/// Run a batch as a fresh top-level pool of exactly `workers` threads
/// (the caller participates as worker 0, so `workers - 1` are spawned).
fn run_root<T, R, F>(label: &'static str, items: Vec<T>, workers: usize, f: F) -> (Vec<R>, PoolReport)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let batch = Batch::new(label, items, &f);
    let jobs = batch.jobs();
    let pool = PoolCore::new(workers);
    // Pre-spawn distribution: round-robin, owner rules trivially satisfied
    // because no worker exists yet and `thread::scope` spawns give the
    // deques a happens-before edge to their owners.
    for (i, job) in jobs.iter().enumerate() {
        pool.deques[i % workers].push(job as *const Job as usize);
    }
    G_POOLS.fetch_add(1, Ordering::Relaxed);
    G_BATCHES.fetch_add(1, Ordering::Relaxed);
    std::thread::scope(|s| {
        for w in 1..workers {
            let pool = &pool;
            s.spawn(move || worker_loop(pool, w, None));
        }
        worker_loop(&pool, 0, Some((&batch.header.done, n)));
        pool.shutdown.store(true, Ordering::Release);
    });
    let report = PoolReport {
        per_worker: pool
            .counters
            .iter()
            .map(|c| WorkerReport {
                tasks: c.tasks.load(Ordering::Relaxed),
                steals: c.steals.load(Ordering::Relaxed),
                idle_ns: c.idle_ns.load(Ordering::Relaxed),
            })
            .collect(),
    };
    drop(jobs);
    (batch.finish(), report)
}

// ---------------------------------------------------------------------------
// Public map entry points
// ---------------------------------------------------------------------------

/// Parallel map with work stealing: applies `f` to each item, returning
/// results in input order (determinism by reduction order — see the
/// module docs).  Top-level calls run a pool of `min(workers, n)` threads;
/// calls from inside a pool worker become stealable nested batches on the
/// shared pool regardless of `workers` (the pool owns the thread budget).
pub fn ws_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    ws_map_named("task", items, workers, f)
}

/// [`ws_map`] with a batch label used when naming a panicking job.
pub fn ws_map_named<T, R, F>(label: &'static str, items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    if let Some((me, pool)) = WORKER.with(|w| w.get()) {
        // Inside a pool: the pool pointer is valid for the worker's whole
        // loop, which strictly contains this call.
        return run_nested(unsafe { &*pool }, me, label, items, f);
    }
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    run_root(label, items, workers.min(n), f).0
}

/// [`ws_map_named`] for fan-outs whose items spawn nested batches: the
/// pool keeps *all* `workers` threads even when there are fewer items, so
/// the extra workers immediately steal the items' nested jobs (this is
/// what turns a figure assembly into a cross-leg pipeline).  Top-level
/// only; nested calls behave exactly like [`ws_map_named`].
pub fn ws_map_pool<T, R, F>(label: &'static str, items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    ws_map_pool_report(label, items, workers, f).0
}

/// [`ws_map_pool`] additionally returning the pool's per-worker telemetry.
/// When the call is serial (one worker / one item) or nested in an outer
/// pool, the report is empty — the outer pool owns the counters.
pub fn ws_map_pool_report<T, R, F>(
    label: &'static str,
    items: Vec<T>,
    workers: usize,
    f: F,
) -> (Vec<R>, PoolReport)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if let Some((me, pool)) = WORKER.with(|w| w.get()) {
        if items.len() <= 1 {
            return (items.into_iter().map(f).collect(), PoolReport::default());
        }
        return (
            run_nested(unsafe { &*pool }, me, label, items, f),
            PoolReport::default(),
        );
    }
    if workers <= 1 || items.is_empty() {
        return (items.into_iter().map(f).collect(), PoolReport::default());
    }
    run_root(label, items, workers, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deque_is_lifo_for_the_owner() {
        let d = Deque::with_capacity(4);
        for v in 1..=10usize {
            d.push(v);
        }
        for v in (1..=10usize).rev() {
            assert_eq!(d.pop(), Some(v));
        }
        assert_eq!(d.pop(), None);
        assert_eq!(d.pop(), None, "pop on empty must stay empty");
    }

    #[test]
    fn deque_steals_fifo_and_grows() {
        let d = Deque::with_capacity(2);
        for v in 1..=9usize {
            d.push(v); // forces repeated growth from cap 2
        }
        assert_eq!(d.steal(), Steal::Data(1));
        assert_eq!(d.steal(), Steal::Data(2));
        assert_eq!(d.pop(), Some(9));
        assert_eq!(d.steal(), Steal::Data(3));
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn empty_deque_reports_empty_to_thieves() {
        let d = Deque::default();
        assert_eq!(d.steal(), Steal::Empty);
        d.push(7);
        assert_eq!(d.pop(), Some(7));
        assert_eq!(d.steal(), Steal::Empty);
    }

    #[test]
    fn ws_map_matches_serial_in_order() {
        let items: Vec<usize> = (0..257).collect();
        let serial: Vec<usize> = items.iter().map(|x| x * 3 + 1).collect();
        let par = ws_map(items, 4, |x| x * 3 + 1);
        assert_eq!(par, serial);
    }

    #[test]
    fn ws_map_handles_empty_and_single() {
        assert!(ws_map(Vec::<usize>::new(), 4, |x| x).is_empty());
        assert_eq!(ws_map(vec![5usize], 4, |x| x + 1), vec![6]);
    }

    #[test]
    fn nested_maps_share_the_pool_and_stay_ordered() {
        let (out, report) = ws_map_pool_report("outer", (0..4u64).collect(), 4, |leg| {
            let inner: Vec<u64> = (0..16).map(|k| leg * 100 + k).collect();
            ws_map_named("inner", inner, 4, |k| k * 7)
        });
        for (leg, row) in out.iter().enumerate() {
            let want: Vec<u64> = (0..16).map(|k| (leg as u64 * 100 + k) * 7).collect();
            assert_eq!(*row, want);
        }
        assert_eq!(report.per_worker.len(), 4);
        assert_eq!(report.tasks(), 4 + 4 * 16, "4 legs + 64 nested jobs");
    }

    #[test]
    fn panics_name_the_batch_and_index() {
        let caught = std::panic::catch_unwind(|| {
            ws_map_named("mc-sample", (0..32usize).collect(), 4, |k| {
                if k == 17 {
                    panic!("sample exploded");
                }
                k
            })
        });
        let payload = caught.expect_err("the panic must propagate");
        let msg = panic_message(&payload);
        assert!(msg.contains("mc-sample[17]"), "panic message was: {msg}");
        assert!(msg.contains("sample exploded"), "panic message was: {msg}");
    }

    #[test]
    fn telemetry_counts_tasks() {
        let before = stats();
        let _ = ws_map((0..64usize).collect(), 4, |x| x + 1);
        let after = stats();
        assert!(after.tasks >= before.tasks + 64);
        assert!(after.pools >= before.pools + 1);
        assert!(after.batches >= before.batches + 1);
    }
}
