//! Small statistics helpers shared by the models, optimizers and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples.
pub fn std_pop(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    (xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (NaN-ignoring); +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}

/// Maximum (NaN-ignoring); -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Samples folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var_pop(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std_pop(&self) -> f64 {
        self.var_pop().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_pop(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, 3.5, -1.0, 0.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_pop() - std_pop(&xs)).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_pop(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_edge_cases() {
        // Single sample: every percentile is that sample, bit-for-bit.
        let one = [3.25];
        for p in [0.0, 7.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&one, p).to_bits(), 3.25f64.to_bits());
        }
        // All-equal samples: interpolation between equal order statistics
        // returns the common value exactly (the `lo == hi` short-circuit
        // and the `v[lo] + frac * 0` path agree bitwise).
        let same = [0.1; 7];
        for p in [0.0, 33.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&same, p).to_bits(), 0.1f64.to_bits());
        }
        // Exact-rank hits do not interpolate: p95 over 21 samples lands
        // on rank 19 exactly.
        let xs: Vec<f64> = (0..21).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 95.0).to_bits(), 19.0f64.to_bits());
        // Monotonicity in p.
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        let mut prev = f64::NEG_INFINITY;
        for p in 0..=100 {
            let v = percentile(&xs, p as f64);
            assert!(v >= prev, "percentile not monotone at p={p}");
            prev = v;
        }
    }
}
