"""L2: the HeM3D design-evaluation compute graph (build-time JAX).

Two exported entry points compose the L1 Pallas kernels:

  * ``moo_eval_model``    — the DSE hot path.  Scores a batch of candidate
    designs against the paper's four objectives (Eqs. (1)-(8)).  The rust
    coordinator (L3) feeds it routing incidence / traffic / power tensors and
    reads back (lat, umean, usigma, tmax).
  * ``thermal_solve_model`` — the 3D-ICE-substitute detailed solve used to
    validate Pareto winners (Eq. (10)'s Temp(d)).

Both are lowered once by ``aot.py`` to HLO text; Python never runs on the
DSE path.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.noc_moo import moo_eval
from compile.kernels.thermal import thermal_solve

# Canonical artifact shapes — paper §5.1: 64 tiles (8 CPU + 40 GPU + 16 LLC),
# SWNoC with mesh-equivalent link count over a 4x4x4 tile grid, 8 traffic
# windows, 16 vertical stacks.  The batch sizes amortize PJRT dispatch.
N_TILES = 64
N_LINKS = 144
N_PAIRS = N_TILES * N_TILES
N_WINDOWS = 8
N_STACKS = 16
MOO_BATCH = 16

# Thermal grid: 4 tile tiers -> Z cell layers (silicon + inter-tier material
# pairs + base), XY at 2x2 cells per tile column (§ thermal/grid.rs mirrors
# this exactly).
TH_Z = 10
TH_Y = 8
TH_X = 8
TH_BATCH = 8
# Two-grid relaxation schedule (see kernels/thermal.py): 3 cycles of a
# coarse column-collapsed solve + 400 fine Pallas sweeps.
TH_CYCLES = 3
TH_IT2D = 300
TH_IT3D = 400


def moo_eval_model(q, f, latw, pact, cth, ssel):
    """Batched Eq.(1)-(8) objective evaluation; returns a 4-tuple of (B,)."""
    lat, umean, usigma, tmax = moo_eval(q, f, latw, pact, cth, ssel)
    return lat, umean, usigma, tmax


def thermal_solve_model(pow_, gdn, gup, glat, gamb):
    """Detailed steady-state solve; returns (B, Z, Y, X) rise and (B,) peak."""
    t = thermal_solve(pow_, gdn, gup, glat, gamb,
                      cycles=TH_CYCLES, it2d=TH_IT2D, it3d=TH_IT3D)
    return t, jnp.max(t, axis=(1, 2, 3))


def moo_eval_specs():
    """ShapeDtypeStructs for lowering moo_eval_model."""
    import jax
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((MOO_BATCH, N_LINKS, N_PAIRS), f32),
        jax.ShapeDtypeStruct((N_WINDOWS, N_PAIRS), f32),
        jax.ShapeDtypeStruct((MOO_BATCH, N_PAIRS), f32),
        jax.ShapeDtypeStruct((MOO_BATCH, N_WINDOWS, N_TILES), f32),
        jax.ShapeDtypeStruct((N_TILES,), f32),
        jax.ShapeDtypeStruct((N_TILES, N_STACKS), f32),
    )


def thermal_solve_specs():
    """ShapeDtypeStructs for lowering thermal_solve_model."""
    import jax
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((TH_BATCH, TH_Z, TH_Y, TH_X), f32),
        jax.ShapeDtypeStruct((TH_Z,), f32),
        jax.ShapeDtypeStruct((TH_Z,), f32),
        jax.ShapeDtypeStruct((TH_Z,), f32),
        jax.ShapeDtypeStruct((TH_Z,), f32),
    )
