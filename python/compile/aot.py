"""AOT: lower the L2 models to HLO text for the rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
  moo_eval.hlo.txt       — batched Eq.(1)-(8) design scoring
  thermal_solve.hlo.txt  — batched 3D-ICE-substitute Jacobi solve
  meta.json              — shapes + layout contract checked by rust at load
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # --- moo_eval -----------------------------------------------------------
    lowered = jax.jit(model.moo_eval_model).lower(*model.moo_eval_specs())
    text = to_hlo_text(lowered)
    path = os.path.join(args.out, "moo_eval.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {len(text)} chars to {path}")

    # --- thermal_solve ------------------------------------------------------
    lowered = jax.jit(model.thermal_solve_model).lower(
        *model.thermal_solve_specs())
    text = to_hlo_text(lowered)
    path = os.path.join(args.out, "thermal_solve.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {len(text)} chars to {path}")

    # --- meta ---------------------------------------------------------------
    meta = {
        "moo_eval": {
            "batch": model.MOO_BATCH,
            "tiles": model.N_TILES,
            "links": model.N_LINKS,
            "pairs": model.N_PAIRS,
            "windows": model.N_WINDOWS,
            "stacks": model.N_STACKS,
            "inputs": ["q[B,L,P]", "f[W,P]", "latw[B,P]", "pact[B,W,N]",
                       "cth[N]", "ssel[N,S]"],
            "outputs": ["lat[B]", "umean[B]", "usigma[B]", "tmax[B]"],
        },
        "thermal_solve": {
            "batch": model.TH_BATCH,
            "z": model.TH_Z,
            "y": model.TH_Y,
            "x": model.TH_X,
            "cycles": model.TH_CYCLES,
            "it2d": model.TH_IT2D,
            "it3d": model.TH_IT3D,
            "inputs": ["pow[B,Z,Y,X]", "gdn[Z]", "gup[Z]", "glat[Z]", "gamb[Z]"],
            "outputs": ["t[B,Z,Y,X]", "tpeak[B]"],
        },
    }
    path = os.path.join(args.out, "meta.json")
    with open(path, "w") as fh:
        json.dump(meta, fh, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
