"""L1 Pallas kernel: batched MOO objective evaluation (paper Eqs. (1)-(8)).

One kernel invocation scores one candidate HeM3D design; the pallas grid
batches B designs.  Per design the kernel computes:

  * link utilisations  U[l, w] = sum_p Q[l, p] * F[w, p]        (Eq. 2)
    — the many-to-few-to-many traffic pushed through each link, per
    traffic window.  This is the MXU-shaped contraction: (L, P) @ (P, W)
    with P = N^2 = 4096 as the K dimension.
  * umean  = mean_{w,l} U                                        (Eq. 3, 5)
  * usigma = mean_w std_l U[:, w]                                (Eq. 4, 6)
  * lat    = mean_w sum_p LATW[p] * F[w, p]                      (Eq. 1)
    where LATW already folds (r * h_ij + d_ij) * cpu_llc_mask / (C*M).
  * tmax   = max_{w,s} sum_n PACT[w, n] * CTH[n] * SSEL[n, s]    (Eq. 7, 8)
    — the vertical-stack resistive thermal model.  CTH[n] folds the
    cumulative vertical resistance (sum_{j<=tier(n)} R_j + R_b) * T_H for
    the position n; ambient offset is added by the caller (rust L3).

TPU mapping (estimated; interpret=True on CPU for correctness): Q block of
(L, P) tiles as 128x512 MXU feeds; U accumulator (L, W) lives in VMEM
scratch (< 5 KB); the latency / thermal terms are rank-1 fused epilogues.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moo_kernel(q_ref, f_ref, latw_ref, pact_ref, cth_ref, ssel_ref,
                lat_ref, umean_ref, usigma_ref, tmax_ref):
    q = q_ref[0]          # (L, P) routing incidence for this design
    f = f_ref[...]        # (W, P) windowed traffic frequencies
    latw = latw_ref[0]    # (P,)   latency weights for this design
    pact = pact_ref[0]    # (W, N) per-tile power per window
    cth = cth_ref[...]    # (N,)   cumulative stack resistance coefficient
    ssel = ssel_ref[...]  # (N, S) position -> vertical stack one-hot

    # Eq. (2): expected utilisation of every link under every window.
    u = jnp.dot(q, f.T, preferred_element_type=jnp.float32)     # (L, W)

    # Eqs. (3)+(5): time-averaged mean link load.
    umean_ref[...] = jnp.mean(u)[None]

    # Eqs. (4)+(6): time-averaged stddev of link load (per-window sigma).
    mu_w = jnp.mean(u, axis=0, keepdims=True)                    # (1, W)
    usigma_ref[...] = jnp.mean(
        jnp.sqrt(jnp.mean((u - mu_w) ** 2, axis=0)))[None]

    # Eq. (1): CPU<->LLC latency, averaged over windows.
    lat_ref[...] = jnp.mean(jnp.dot(f, latw))[None]

    # Eqs. (7)+(8): per-stack cumulative heating, max over windows+stacks.
    ts = jnp.dot(pact * cth[None, :], ssel,
                 preferred_element_type=jnp.float32)             # (W, S)
    tmax_ref[...] = jnp.max(ts)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def moo_eval(q, f, latw, pact, cth, ssel, *, interpret=True):
    """Batched design scoring.

    Args:
      q:    (B, L, P) float32 — link-pair incidence q_ijk per design.
      f:    (W, P)    float32 — windowed communication frequency f_ij(t).
      latw: (B, P)    float32 — latency weights (r*h+d)*mask/(C*M).
      pact: (B, W, N) float32 — per-position power per window.
      cth:  (N,)      float32 — Eq.(7) stack coefficient (incl. T_H factor).
      ssel: (N, S)    float32 — position->stack one-hot.

    Returns:
      (lat, umean, usigma, tmax), each (B,) float32.  Ambient temperature is
      NOT included in tmax — the caller adds T_amb.
    """
    b, l, p = q.shape
    w = f.shape[0]
    n, s = ssel.shape
    out_shape = [jax.ShapeDtypeStruct((b,), jnp.float32) for _ in range(4)]
    grid = (b,)
    return pl.pallas_call(
        _moo_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((w, p), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (i, 0)),
            pl.BlockSpec((1, w, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)) for _ in range(4)],
        out_shape=out_shape,
        interpret=interpret,
    )(q, f, latw, pact, cth, ssel)
