"""L1 Pallas kernel: one Jacobi sweep of the 3D-ICE-substitute RC thermal grid.

Finite-volume steady-state heat conduction over a (Z, Y, X) cell grid:

    T'[z,y,x] = ( P[z,y,x]
                 + g_dn[z] * T[z-1,y,x]      (toward the heat sink; z=0 couples
                                              to ambient through g_dn[0])
                 + g_up[z] * T[z+1,y,x]      (away from the sink; 0 at z=Z-1)
                 + g_lat[z] * sum_4nbr T )   (lateral spreading, adiabatic
                                              chip edges)
                / ( g_dn[z] + g_up[z] + g_lat[z] * n_nbr + g_amb[z] )

where g_amb[z] is a per-layer convective shunt straight to ambient — zero for
a dry stack, non-zero at the inter-tier layers when the TSV design uses the
paper's microfluidic cooling [20] (coolant at ambient temperature).

Temperatures are rises over ambient.  The per-layer conductances encode the
TSV-vs-M3D physical difference (Table 1): TSV inserts a poorly conducting
bonding layer between tiers; M3D an extremely thin ILD.  The paper's Fig 4
behaviour (lateral spreading + vertical accumulation in TSV) emerges from
these constants.

TPU mapping (estimated): red-black would fit the VPU directly; we use Jacobi
(two buffers) because it keeps the sweep a pure shifted-add stencil, lanes
padded to 128 along X.  Per-design state (2 fields x Z*Y*X f32 ~ 20 KB) is
VMEM-resident across the whole fori_loop — zero HBM traffic between sweeps.
interpret=True on CPU for correctness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_kernel(pow_ref, t_ref, gdn_ref, gup_ref, glat_ref, inv_den_ref,
                  out_ref):
    p = pow_ref[0]            # (Z, Y, X) heat input per cell [W]
    t = t_ref[0]              # (Z, Y, X) current temperature rise [K]
    gdn = gdn_ref[...]        # (Z,) conductance to layer below (z-1 / ambient)
    gup = gup_ref[...]        # (Z,) conductance to layer above (z+1)
    glat = glat_ref[...]      # (Z,) lateral conductance within the layer
    inv_den = inv_den_ref[...]  # (Z, Y, X) precomputed 1/denominator

    z, y, x = t.shape

    # Vertical neighbours (zero-padded; gup[z-1]==gdn[z] symmetry is the
    # caller's responsibility).
    t_below = jnp.concatenate([jnp.zeros((1, y, x), t.dtype), t[:-1]], axis=0)
    t_above = jnp.concatenate([t[1:], jnp.zeros((1, y, x), t.dtype)], axis=0)

    # Lateral neighbours, zero-padded (adiabatic chip edges: the true
    # neighbour multiplicity is already folded into inv_den).
    t_n = jnp.concatenate([jnp.zeros((z, 1, x), t.dtype), t[:, :-1]], axis=1)
    t_s = jnp.concatenate([t[:, 1:], jnp.zeros((z, 1, x), t.dtype)], axis=1)
    t_w = jnp.concatenate([jnp.zeros((z, y, 1), t.dtype), t[:, :, :-1]], axis=2)
    t_e = jnp.concatenate([t[:, :, 1:], jnp.zeros((z, y, 1), t.dtype)], axis=2)

    gdn3 = gdn[:, None, None]
    gup3 = gup[:, None, None]
    gl3 = glat[:, None, None]

    num = p + gdn3 * t_below + gup3 * t_above + gl3 * (t_n + t_s + t_w + t_e)
    out_ref[0] = num * inv_den


def _inv_denominator(z, y, x, gdn, gup, glat, gamb):
    """(Z, Y, X) reciprocal Jacobi denominator — loop-invariant, computed
    once at L2 instead of per sweep.  (Also sidesteps an xla_extension 0.5.1
    miscompilation of concatenated-constant neighbour counts inside the
    pallas-emulated kernel; see DESIGN.md §Perf.)"""
    iy = jnp.arange(y)
    ix = jnp.arange(x)
    n_y = jnp.where(iy == 0, 1.0, jnp.where(iy == y - 1, 1.0, 2.0))
    n_x = jnp.where(ix == 0, 1.0, jnp.where(ix == x - 1, 1.0, 2.0))
    n_nbr = n_y[:, None] + n_x[None, :]                            # (Y, X)
    den = (gdn[:, None, None] + gup[:, None, None] + gamb[:, None, None]
           + glat[:, None, None] * n_nbr[None, :, :])
    return 1.0 / den


def _sweep(pow_, t, gdn, gup, glat, inv_den, *, interpret=True):
    b, z, y, x = t.shape
    return pl.pallas_call(
        _sweep_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, z, y, x), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, z, y, x), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((z,), lambda i: (0,)),
            pl.BlockSpec((z,), lambda i: (0,)),
            pl.BlockSpec((z,), lambda i: (0,)),
            pl.BlockSpec((z, y, x), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, z, y, x), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, z, y, x), jnp.float32),
        interpret=interpret,
    )(pow_, t, gdn, gup, glat, inv_den)


def _residual(pow_, t, gdn, gup, glat, inv_den):
    """r = P - G*T (same stencil as the sweep; plain jnp at L2)."""
    zero_z = jnp.zeros_like(t[:, :1])
    zero_y = jnp.zeros_like(t[:, :, :1])
    zero_x = jnp.zeros_like(t[:, :, :, :1])
    t_below = jnp.concatenate([zero_z, t[:, :-1]], axis=1)
    t_above = jnp.concatenate([t[:, 1:], zero_z], axis=1)
    lat = (jnp.concatenate([zero_y, t[:, :, :-1]], axis=2)
           + jnp.concatenate([t[:, :, 1:], zero_y], axis=2)
           + jnp.concatenate([zero_x, t[:, :, :, :-1]], axis=3)
           + jnp.concatenate([t[:, :, :, 1:], zero_x], axis=3))
    num = (pow_ + gdn[None, :, None, None] * t_below
           + gup[None, :, None, None] * t_above
           + glat[None, :, None, None] * lat)
    return num - t / inv_den[None]


def _jacobi2d(p2, gl2, gs, n_iters):
    """Jacobi on the column-collapsed (B, Y, X) problem (coarse level)."""
    b, y, x = p2.shape
    iy = jnp.arange(y)
    ix = jnp.arange(x)
    n_y = jnp.where((iy == 0) | (iy == y - 1), 1.0, 2.0)
    n_x = jnp.where((ix == 0) | (ix == x - 1), 1.0, 2.0)
    inv_den2 = (1.0 / (gs + gl2 * (n_y[:, None] + n_x[None, :]))).astype(
        jnp.float32)

    def body(_, t2):
        zero_y = jnp.zeros_like(t2[:, :1])
        zero_x = jnp.zeros_like(t2[:, :, :1])
        lat = (jnp.concatenate([zero_y, t2[:, :-1]], axis=1)
               + jnp.concatenate([t2[:, 1:], zero_y], axis=1)
               + jnp.concatenate([zero_x, t2[:, :, :-1]], axis=2)
               + jnp.concatenate([t2[:, :, 1:], zero_x], axis=2))
        return (p2 + gl2 * lat) * inv_den2[None]

    return jax.lax.fori_loop(0, n_iters, body, jnp.zeros_like(p2))


@functools.partial(
    jax.jit, static_argnames=("cycles", "it2d", "it3d", "interpret"))
def thermal_solve(pow_, gdn, gup, glat, gamb, *, cycles=3, it2d=300,
                  it3d=400, interpret=True):
    """Steady-state temperature-rise field by two-level relaxation.

    Plain Jacobi stalls on the stiff M3D stack (huge inter-layer vs tiny
    sink conductance => the laterally-varying global mode decays at
    ~1e-3/sweep; 600 sweeps under-predict the peak 3x).  The fix is a
    two-grid scheme: each cycle solves the column-collapsed (Y, X) problem
    for the residual (columns are near-isothermal), broadcasts the
    correction, and refines vertical structure with `it3d` Pallas sweeps.
    3 cycles land within 0.03% of the exact dense solution for both
    technology stacks (see tests/test_kernel.py).

    Args:
      pow_: (B, Z, Y, X) float32 — heat injected per cell [W].
      gdn:  (Z,) float32 — conductance to the layer below (gdn[0]: to sink).
      gup:  (Z,) float32 — conductance to the layer above (gup[Z-1] == 0).
      glat: (Z,) float32 — lateral conductance within each layer.
      gamb: (Z,) float32 — convective shunt to ambient (microfluidic cooling;
            all-zero for a dry stack).

    Returns:
      (B, Z, Y, X) float32 temperature rise over ambient [K].
    """
    b, z, y, x = pow_.shape
    inv_den = _inv_denominator(z, y, x, gdn, gup, glat, gamb).astype(jnp.float32)
    gl2 = jnp.sum(glat)
    gs = gdn[0] + jnp.sum(gamb)

    t = jnp.zeros_like(pow_)
    for _ in range(cycles):
        r = _residual(pow_, t, gdn, gup, glat, inv_den)
        t2 = _jacobi2d(jnp.sum(r, axis=1), gl2, gs, it2d)
        t = t + t2[:, None, :, :]

        def body(_, tt):
            return _sweep(pow_, tt, gdn, gup, glat, inv_den,
                          interpret=interpret)

        t = jax.lax.fori_loop(0, it3d, body, t)
    return t
