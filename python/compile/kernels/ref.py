"""Pure-jnp oracles for the Pallas kernels — the build-time correctness bar.

These are transliterations of the paper's Eqs. (1)-(8) and of the textbook
Jacobi finite-volume update, written with no pallas, no clever reshaping, so
that a mismatch unambiguously implicates the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def moo_eval_ref(q, f, latw, pact, cth, ssel):
    """Reference for kernels.noc_moo.moo_eval.  Shapes as documented there."""
    # Eq. (2): u[b, w, l] = sum_p q[b, l, p] * f[w, p]
    u = jnp.einsum("blp,wp->bwl", q, f)

    # Eqs. (3)+(5)
    umean = jnp.mean(u, axis=(1, 2))

    # Eqs. (4)+(6): per-window population stddev over links, then window avg.
    usigma = jnp.mean(jnp.std(u, axis=2), axis=1)

    # Eq. (1): window-averaged weighted latency.
    lat = jnp.mean(jnp.einsum("bp,wp->bw", latw, f), axis=1)

    # Eqs. (7)+(8): stack heating, max over windows and stacks.
    ts = jnp.einsum("bwn,n,ns->bws", pact, cth, ssel)
    tmax = jnp.max(ts, axis=(1, 2))

    return lat, umean, usigma, tmax


def thermal_sweep_ref(pow_, t, gdn, gup, glat, gamb):
    """One Jacobi sweep; shapes as in kernels.thermal (batched)."""
    b, z, y, x = t.shape
    zero_z = jnp.zeros((b, 1, y, x), t.dtype)
    zero_y = jnp.zeros((b, z, 1, x), t.dtype)
    zero_x = jnp.zeros((b, z, y, 1), t.dtype)

    t_below = jnp.concatenate([zero_z, t[:, :-1]], axis=1)
    t_above = jnp.concatenate([t[:, 1:], zero_z], axis=1)
    t_n = jnp.concatenate([zero_y, t[:, :, :-1]], axis=2)
    t_s = jnp.concatenate([t[:, :, 1:], zero_y], axis=2)
    t_w = jnp.concatenate([zero_x, t[:, :, :, :-1]], axis=3)
    t_e = jnp.concatenate([t[:, :, :, 1:], zero_x], axis=3)

    ones = jnp.ones_like(t)
    n_n = jnp.concatenate([zero_y, ones[:, :, :-1]], axis=2)
    n_s = jnp.concatenate([ones[:, :, 1:], zero_y], axis=2)
    n_w = jnp.concatenate([zero_x, ones[:, :, :, :-1]], axis=3)
    n_e = jnp.concatenate([ones[:, :, :, 1:], zero_x], axis=3)
    n_nbr = n_n + n_s + n_w + n_e

    gdn4 = gdn[None, :, None, None]
    gup4 = gup[None, :, None, None]
    gl4 = glat[None, :, None, None]

    num = pow_ + gdn4 * t_below + gup4 * t_above + gl4 * (t_n + t_s + t_w + t_e)
    den = gdn4 + gup4 + gl4 * n_nbr + gamb[None, :, None, None]
    return num / den


def thermal_solve_ref(pow_, gdn, gup, glat, gamb, n_iters=600):
    """Fixed-count Jacobi relaxation (reference for one kernel sweep chain)."""
    t = jnp.zeros_like(pow_)
    for _ in range(n_iters):
        t = thermal_sweep_ref(pow_, t, gdn, gup, glat, gamb)
    return t


def thermal_solve_exact(pow_, gdn, gup, glat, gamb):
    """Independent oracle: assemble the full conductance matrix and solve it
    densely with numpy — no iteration, no shared code with the kernel.
    Shapes as in kernels.thermal (batched)."""
    import numpy as np

    pow_ = np.asarray(pow_, dtype=np.float64)
    gdn = np.asarray(gdn, dtype=np.float64)
    gup = np.asarray(gup, dtype=np.float64)
    glat = np.asarray(glat, dtype=np.float64)
    gamb = np.asarray(gamb, dtype=np.float64)
    b, z, y, x = pow_.shape
    n = z * y * x

    def idx(zz, yy, xx):
        return (zz * y + yy) * x + xx

    g = np.zeros((n, n))
    for zz in range(z):
        for yy in range(y):
            for xx in range(x):
                i = idx(zz, yy, xx)
                diag = gdn[zz] + gamb[zz]
                if zz > 0:
                    g[i, idx(zz - 1, yy, xx)] -= gdn[zz]
                if zz + 1 < z:
                    diag += gup[zz]
                    g[i, idx(zz + 1, yy, xx)] -= gup[zz]
                for (ny_, nx_) in ((yy - 1, xx), (yy + 1, xx), (yy, xx - 1), (yy, xx + 1)):
                    if 0 <= ny_ < y and 0 <= nx_ < x:
                        diag += glat[zz]
                        g[i, idx(zz, ny_, nx_)] -= glat[zz]
                g[i, i] = diag

    out = np.empty_like(pow_)
    for bb in range(b):
        out[bb] = np.linalg.solve(g, pow_[bb].ravel()).reshape(z, y, x)
    return out
