"""Kernel-vs-oracle correctness — the CORE build-time signal.

hypothesis sweeps shapes and magnitudes; every Pallas kernel must match its
pure-jnp (or exact-numpy) oracle within float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.noc_moo import moo_eval
from compile.kernels.thermal import thermal_solve


def _rand(rng, shape, scale=1.0):
    return (rng.random(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# moo_eval
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    l=st.integers(2, 24),
    n=st.integers(4, 12),
    w=st.integers(1, 6),
    s=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_moo_eval_matches_ref_across_shapes(b, l, n, w, s, seed):
    rng = np.random.default_rng(seed)
    p = n * n
    q = (rng.random((b, l, p)) < 0.3).astype(np.float32)
    f = _rand(rng, (w, p), 0.2)
    latw = _rand(rng, (b, p))
    pact = _rand(rng, (b, w, n), 4.0)
    cth = _rand(rng, (n,)) + 0.1
    ssel = np.zeros((n, s), np.float32)
    for i in range(n):
        ssel[i, rng.integers(0, s)] = 1.0

    got = moo_eval(q, f, latw, pact, cth, ssel)
    want = ref.moo_eval_ref(q, f, latw, pact, cth, ssel)
    for g, wnt, name in zip(got, want, ["lat", "umean", "usigma", "tmax"]):
        np.testing.assert_allclose(g, wnt, rtol=2e-5, atol=2e-5, err_msg=name)


def test_moo_eval_zero_traffic_zeroes_link_objectives():
    rng = np.random.default_rng(0)
    b, l, n, w, s = 2, 6, 6, 3, 4
    p = n * n
    q = (rng.random((b, l, p)) < 0.5).astype(np.float32)
    f = np.zeros((w, p), np.float32)
    latw = _rand(rng, (b, p))
    pact = _rand(rng, (b, w, n), 2.0)
    cth = _rand(rng, (n,)) + 0.5
    ssel = np.eye(n, s, dtype=np.float32)
    lat, umean, usigma, tmax = moo_eval(q, f, latw, pact, cth, ssel)
    assert np.allclose(lat, 0) and np.allclose(umean, 0) and np.allclose(usigma, 0)
    assert np.all(np.asarray(tmax) > 0)  # thermal is traffic-independent here


def test_moo_eval_is_deterministic():
    rng = np.random.default_rng(7)
    b, l, n, w, s = 2, 8, 8, 4, 4
    p = n * n
    args = (
        (rng.random((b, l, p)) < 0.2).astype(np.float32),
        _rand(rng, (w, p), 0.1),
        _rand(rng, (b, p)),
        _rand(rng, (b, w, n)),
        _rand(rng, (n,)) + 0.1,
        np.eye(n, s, dtype=np.float32),
    )
    a = moo_eval(*args)
    b_ = moo_eval(*args)
    for x, y in zip(a, b_):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# thermal_solve
# ---------------------------------------------------------------------------

def _ladder(rng, z, stiff):
    """Random physically-plausible conductance vectors."""
    if stiff:
        gdn = np.concatenate(
            [[0.05], (rng.random(z - 1) * 30 + 5)]).astype(np.float32)
    else:
        gdn = (rng.random(z) * 1.5 + 0.3).astype(np.float32)
    gup = np.concatenate([gdn[1:], [0.0]]).astype(np.float32)
    glat = (rng.random(z) * 0.05 + 0.005).astype(np.float32)
    gamb = np.where(rng.random(z) < 0.3, rng.random(z) * 0.1, 0.0).astype(np.float32)
    return gdn, gup, glat, gamb


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    z=st.integers(3, 8),
    y=st.integers(2, 6),
    x=st.integers(2, 6),
    stiff=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_thermal_solve_matches_exact_oracle(b, z, y, x, stiff, seed):
    rng = np.random.default_rng(seed)
    gdn, gup, glat, gamb = _ladder(rng, z, stiff)
    pw = (rng.random((b, z, y, x)) * 0.5).astype(np.float32)
    got = np.asarray(thermal_solve(pw, gdn, gup, glat, gamb))
    want = ref.thermal_solve_exact(pw, gdn, gup, glat, gamb)
    peak = want.max()
    np.testing.assert_allclose(got, want, rtol=0, atol=max(1e-2 * peak, 1e-4))


def test_thermal_solve_is_linear_in_power():
    rng = np.random.default_rng(3)
    z = 6
    gdn, gup, glat, gamb = _ladder(rng, z, True)
    pw = (rng.random((2, z, 4, 4)) * 0.3).astype(np.float32)
    t1 = np.asarray(thermal_solve(pw, gdn, gup, glat, gamb))
    t2 = np.asarray(thermal_solve(2.0 * pw, gdn, gup, glat, gamb))
    np.testing.assert_allclose(t2, 2.0 * t1, rtol=1e-4, atol=1e-5)


def test_thermal_zero_power_is_cold():
    rng = np.random.default_rng(4)
    gdn, gup, glat, gamb = _ladder(rng, 5, False)
    pw = np.zeros((1, 5, 3, 3), np.float32)
    t = np.asarray(thermal_solve(pw, gdn, gup, glat, gamb))
    assert np.allclose(t, 0.0)


def test_ambient_shunt_cools():
    rng = np.random.default_rng(5)
    z = 6
    gdn, gup, glat, _ = _ladder(rng, z, False)
    pw = (rng.random((1, z, 4, 4)) * 0.5).astype(np.float32)
    dry = np.asarray(thermal_solve(pw, gdn, gup, glat, np.zeros(z, np.float32)))
    wet = np.asarray(
        thermal_solve(pw, gdn, gup, glat, np.full(z, 0.2, np.float32)))
    assert wet.max() < dry.max()


def test_sweep_kernel_matches_ref_single_step():
    """One raw Pallas sweep against the jnp reference sweep."""
    from compile.kernels.thermal import _inv_denominator, _sweep

    rng = np.random.default_rng(6)
    b, z, y, x = 2, 4, 3, 5
    gdn, gup, glat, gamb = _ladder(rng, z, False)
    pw = _rand(rng, (b, z, y, x), 0.5)
    t = _rand(rng, (b, z, y, x), 2.0)
    inv_den = np.asarray(_inv_denominator(z, y, x, gdn, gup, glat, gamb),
                         np.float32)
    got = np.asarray(_sweep(pw, t, gdn, gup, glat, inv_den))
    want = np.asarray(ref.thermal_sweep_ref(pw, t, gdn, gup, glat, gamb))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
