"""L2 model shape checks + AOT lowering smoke: the artifacts must lower to
parseable HLO text with the canonical shapes, without a rust toolchain."""

import numpy as np
import jax

from compile import model
from compile.aot import to_hlo_text


def test_moo_eval_model_shapes():
    specs = model.moo_eval_specs()
    rng = np.random.default_rng(0)
    args = [np.asarray(rng.random(s.shape), s.dtype) for s in specs]
    out = model.moo_eval_model(*args)
    assert len(out) == 4
    for o in out:
        assert o.shape == (model.MOO_BATCH,)
        assert np.all(np.isfinite(np.asarray(o)))


def test_thermal_solve_model_shapes():
    specs = model.thermal_solve_specs()
    rng = np.random.default_rng(1)
    pw = np.asarray(rng.random(specs[0].shape) * 0.1, np.float32)
    z = model.TH_Z
    gdn = np.linspace(0.05, 2.0, z).astype(np.float32)
    gup = np.concatenate([gdn[1:], [0.0]]).astype(np.float32)
    glat = np.full(z, 0.02, np.float32)
    gamb = np.zeros(z, np.float32)
    t, peak = model.thermal_solve_model(pw, gdn, gup, glat, gamb)
    assert t.shape == specs[0].shape
    assert peak.shape == (model.TH_BATCH,)
    np.testing.assert_allclose(
        np.asarray(peak), np.asarray(t).max(axis=(1, 2, 3)), rtol=1e-6)


def test_moo_eval_lowers_to_hlo_text():
    lowered = jax.jit(model.moo_eval_model).lower(*model.moo_eval_specs())
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[16,144,4096]" in text  # Q input shape is part of the contract
    assert len(text) > 1000


def test_thermal_lowers_to_hlo_text():
    lowered = jax.jit(model.thermal_solve_model).lower(
        *model.thermal_solve_specs())
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[8,10,8,8]" in text
    # The two-grid schedule embeds three fine while-loops.
    assert text.count("while") >= 3


def test_canonical_dims_match_rust_contract():
    # These constants are mirrored in rust/src/runtime/evaluator.rs::dims —
    # drift breaks the artifact contract.
    assert model.N_TILES == 64
    assert model.N_LINKS == 144
    assert model.N_PAIRS == 4096
    assert model.N_WINDOWS == 8
    assert model.N_STACKS == 16
    assert model.MOO_BATCH == 16
    assert (model.TH_Z, model.TH_Y, model.TH_X, model.TH_BATCH) == (10, 8, 8, 8)
