//! End-to-end driver: the full HeM3D design campaign on all six
//! benchmarks — the headline experiment (Fig 9) plus validation of every
//! winner with the cycle-level NoC simulator and (when `artifacts/` has
//! been built) a cross-check of the Pareto fronts through the AOT PJRT
//! kernels.  The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example design_hem3d`
//! (set HEM3D_EFFORT=full for the figure-grade run; default is `quick`).

use hem3d::config::Tech;
use hem3d::coordinator::campaign::{run_leg, Algo, Effort, LegWorld, Selection};
use hem3d::coordinator::{batch, noc_validate};
use hem3d::coordinator::report::{f, table};
use hem3d::noc::routing::Routing;
use hem3d::opt::Mode;
use hem3d::runtime::Evaluator;

const BENCHES: [&str; 6] = ["bp", "nw", "lv", "lud", "knn", "pf"];

fn main() -> anyhow::Result<()> {
    let effort = match std::env::var("HEM3D_EFFORT").as_deref() {
        Ok("full") => Effort::full(),
        _ => Effort::quick(),
    }
    .with_workers(0); // 0 = all cores (HEM3D_WORKERS overrides)
    let seed = 42u64;
    let evaluator = Evaluator::load("artifacts").ok();
    if evaluator.is_none() {
        eprintln!("note: artifacts/ missing — run `make artifacts` for the PJRT cross-check");
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut gains = Vec::new();
    let mut dts = Vec::new();

    for bench in BENCHES {
        // TSV baseline (= TSV-PT per §5.4) and HeM3D-PO.
        let tsv_world = LegWorld::new(bench, Tech::Tsv, seed);
        let bl = run_leg(&tsv_world, Mode::Pt, Algo::MooStage, Selection::MinEtUnderTth, &effort, seed);
        let m3d_world = LegWorld::new(bench, Tech::M3d, seed);
        let po = run_leg(&m3d_world, Mode::Po, Algo::MooStage, Selection::MinEt, &effort, seed);

        // Validate both winners with the cycle-level NoC simulator.
        let ctx_bl = tsv_world.encode_ctx();
        let r_bl = Routing::build(&bl.winner.design);
        let sim_bl = noc_validate(&ctx_bl, &bl.winner.design, &r_bl, 20_000, seed);
        let ctx_po = m3d_world.encode_ctx();
        let r_po = Routing::build(&po.winner.design);
        let sim_po = noc_validate(&ctx_po, &po.winner.design, &r_po, 20_000, seed);

        // Optional: score the HeM3D Pareto front through the AOT kernels.
        let mut xcheck = "-".to_string();
        if let Some(ev) = &evaluator {
            let designs: Vec<&hem3d::arch::Design> = po
                .candidates
                .iter()
                .take(hem3d::runtime::dims::MOO_BATCH)
                .map(|c| &c.design)
                .collect();
            let art = batch::artifact_scores(ev, &ctx_po, &designs, effort.workers)?;
            let mut max_rel = 0.0f64;
            for (d, a) in designs.iter().zip(art.iter()) {
                let routing = Routing::build(d);
                let n = hem3d::eval::objectives::evaluate(&ctx_po, d, &routing);
                for (x, y) in a.as_vec().iter().zip(n.as_vec().iter()) {
                    max_rel = max_rel.max((x - y).abs() / y.abs().max(1e-9));
                }
            }
            anyhow::ensure!(max_rel < 1e-3, "artifact/native divergence {max_rel:.2e}");
            xcheck = format!("{max_rel:.1e}");
        }

        let gain = 1.0 - po.winner.et / bl.winner.et;
        let dt = bl.winner.temp_c - po.winner.temp_c;
        gains.push(gain);
        dts.push(dt);
        rows.push(vec![
            bench.to_string(),
            f(bl.winner.et, 2),
            f(po.winner.et, 2),
            format!("{:.1}%", 100.0 * gain),
            f(bl.winner.temp_c, 1),
            f(po.winner.temp_c, 1),
            f(dt, 1),
            f(sim_bl.mean_latency, 1),
            f(sim_po.mean_latency, 1),
            xcheck,
        ]);
    }

    println!("\nHeM3D-PO vs TSV-BL — end-to-end campaign (effort: {} )",
        if matches!(std::env::var("HEM3D_EFFORT").as_deref(), Ok("full")) { "full" } else { "quick" });
    println!(
        "{}",
        table(
            &["bench", "ET(tsv)", "ET(hem3d)", "gain", "T(tsv)C", "T(hem3d)C", "dT", "simlat(tsv)", "simlat(m3d)", "pjrt-err"],
            &rows
        )
    );
    let avg_gain = gains.iter().sum::<f64>() / gains.len() as f64;
    let max_gain = gains.iter().cloned().fold(f64::MIN, f64::max);
    let avg_dt = dts.iter().sum::<f64>() / dts.len() as f64;
    let max_dt = dts.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "headline: avg ET gain {:.1}% (paper 14.2%), max {:.1}% (paper 18.3%); avg dT {:.1}C (paper ~18C), max {:.1}C (paper ~19C)",
        100.0 * avg_gain,
        100.0 * max_gain,
        avg_dt,
        max_dt
    );
    Ok(())
}
