//! Quickstart: score one candidate HeM3D design end to end.
//!
//! Builds the paper's 64-tile configuration, generates a Backprop-like
//! traffic trace, evaluates the four MOO objectives (Eqs. 1-8) for a mesh
//! baseline and a small-world NoC, and prints the detailed temperature and
//! execution-time estimates for both.
//!
//! Run: `cargo run --release --example quickstart`

use hem3d::arch::{design::Design, encode::EncodeCtx, geometry::Geometry, tile::TileSet};
use hem3d::config::{ArchConfig, Tech, TechParams};
use hem3d::coordinator::validate::detailed_peak_temp;
use hem3d::eval::objectives::evaluate;
use hem3d::noc::{routing::Routing, topology};
use hem3d::perf::{exec_time, PerfCoeffs};
use hem3d::traffic::{benchmark, generate};
use hem3d::util::Rng;

fn main() {
    // 1. The paper's architecture (8 CPU + 40 GPU + 16 LLC over 4 tiers)
    //    in both integration technologies.
    let cfg = ArchConfig::paper();
    let tiles = TileSet::from_arch(&cfg);
    let profile = benchmark("bp").expect("bp profile");

    for tech_kind in [Tech::Tsv, Tech::M3d] {
        let tech = TechParams::for_tech(tech_kind);
        let geo = Geometry::new(&cfg, &tech);
        let trace = generate(&profile, &tiles, cfg.windows, 42);
        let ctx = EncodeCtx::new(&geo, &tech, &tiles, &trace);

        // 2. Two candidate designs: 3D mesh and a seeded small-world NoC.
        let mesh = Design::with_identity_placement(cfg.n_tiles(), topology::mesh_links(&cfg));
        let mut rng = Rng::seed_from_u64(7);
        let sw_links = topology::swnoc_links(&cfg, &geo, 1.8, &mut rng);
        let swnoc = Design::random_placement(&cfg, sw_links, &mut rng);

        println!("=== {} ===", tech_kind.name());
        for (name, design) in [("mesh", &mesh), ("swnoc", &swnoc)] {
            let routing = Routing::build(design);
            let scores = evaluate(&ctx, design, &routing);
            let et = exec_time(&ctx, &profile, design, &routing, &scores, &PerfCoeffs::default());
            let temp = detailed_peak_temp(&ctx, design);
            println!(
                "{name:>6}: lat={:8.2}  umean={:.4}  usigma={:.4}  ET={:8.2}  T={:5.1}C  (mean hops {:.2})",
                scores.lat,
                scores.umean,
                scores.usigma,
                et.total,
                temp,
                routing.mean_hops()
            );
        }
        println!();
    }
    println!("next: `hem3d optimize --bench bp --tech m3d` runs the full DSE.");
}
