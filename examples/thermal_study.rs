//! Thermal study: reproduce the paper's Fig 4 intuition quantitatively.
//!
//! Sweeps the same workload power across placements (GPUs near vs far from
//! the heat sink), technologies (TSV wet/dry vs M3D) and cooling options,
//! printing peak temperature and the per-tier temperature profile from the
//! finite-volume solver (3D-ICE substitute).
//!
//! Run: `cargo run --release --example thermal_study`

use hem3d::arch::{design::Design, encode::EncodeCtx, geometry::Geometry, tile::TileSet};
use hem3d::config::{ArchConfig, TechParams};
use hem3d::coordinator::validate::{detailed_peak_temp, power_grid};
use hem3d::noc::topology;
use hem3d::runtime::evaluator::dims;
use hem3d::thermal::{GridParams, ThermalGrid, T_AMBIENT_C};
use hem3d::traffic::{benchmark, generate};

fn gpu_placement(near_sink: bool, n: usize) -> Vec<usize> {
    let mut v = Vec::with_capacity(n);
    if near_sink {
        v.extend(8..48); // GPUs at positions 0..40 (low tiers)
        v.extend(0..8);
        v.extend(48..64);
    } else {
        v.extend(48..64); // LLCs near sink, GPUs on top
        v.extend(0..8);
        v.extend(8..48);
    }
    v
}

fn main() {
    let cfg = ArchConfig::paper();
    let tiles = TileSet::from_arch(&cfg);
    let trace = generate(&benchmark("lv").unwrap(), &tiles, cfg.windows, 42);
    let links = topology::mesh_links(&cfg);

    let mut dry_tsv = TechParams::tsv();
    dry_tsv.cooled = false;
    let variants: Vec<(&str, TechParams)> = vec![
        ("tsv+microfluidics", TechParams::tsv()),
        ("tsv dry", dry_tsv),
        ("m3d", TechParams::m3d()),
    ];

    println!("LavaMD worst-window power, by technology and GPU placement:\n");
    println!("{:<20} {:>14} {:>14}", "stack", "GPUs near sink", "GPUs far");
    for (name, tech) in &variants {
        let geo = Geometry::new(&cfg, tech);
        let ctx = EncodeCtx::new(&geo, tech, &tiles, &trace);
        let near = Design::new(gpu_placement(true, cfg.n_tiles()), links.clone());
        let far = Design::new(gpu_placement(false, cfg.n_tiles()), links.clone());
        println!(
            "{:<20} {:>13.1}C {:>13.1}C",
            name,
            detailed_peak_temp(&ctx, &near),
            detailed_peak_temp(&ctx, &far)
        );
    }

    // Per-layer profile for the far placement (the paper's Fig 4 story:
    // TSV accumulates heat across bonding layers, M3D does not).
    println!("\nPer-layer peak temperature, GPUs far from sink:");
    for (name, tech) in &variants {
        let geo = Geometry::new(&cfg, tech);
        let ctx = EncodeCtx::new(&geo, tech, &tiles, &trace);
        let far = Design::new(gpu_placement(false, cfg.n_tiles()), links.clone());
        let stack = tech.layer_stack();
        let grid = ThermalGrid::new(stack.z(), dims::TH_Y, dims::TH_X, GridParams::from_stack(&stack));
        let worst = &trace.windows[0];
        let p = power_grid(&ctx, &far, worst, T_AMBIENT_C + 25.0);
        let t = grid.solve(&p, 600);
        print!("{name:<20}");
        for z in 0..stack.z() {
            let layer_peak = (0..dims::TH_Y * dims::TH_X)
                .map(|i| t[z * dims::TH_Y * dims::TH_X + i])
                .fold(f64::MIN, f64::max);
            print!(" {:5.1}", T_AMBIENT_C + layer_peak);
        }
        println!("   (z=0 near sink)");
    }
}
