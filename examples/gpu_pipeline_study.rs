//! GPU pipeline study (Fig 6 companion): runs the planar synthesis + M3D
//! projection across several netlist seeds and tier counts, reporting the
//! distribution of frequency and energy gains — the robustness check for
//! the 0.70 -> 0.77 GHz claim.
//!
//! Run: `cargo run --release --example gpu_pipeline_study`

use hem3d::timing::m3d::{block_energy_caps, time_block_m3d, M3dConfig};
use hem3d::timing::netlist::{gpu_stage_specs, Process};
use hem3d::timing::pipeline::analyze_gpu_pipeline;
use hem3d::timing::sta::time_block_planar;

fn main() {
    // 1. Seed sweep: how stable are the projected gains?
    println!("Seed sweep (planar 0.70 GHz anchor):");
    println!("{:<6} {:>9} {:>9} {:>8} {:>8}", "seed", "m3d GHz", "gain%", "energy%", "crit");
    let mut freq_gains = Vec::new();
    for seed in [11u64, 42, 97, 1234, 31337] {
        let r = analyze_gpu_pipeline(seed);
        let gain = 100.0 * (r.m3d_freq_ghz / r.planar_freq_ghz - 1.0);
        freq_gains.push(gain);
        println!(
            "{:<6} {:>9.3} {:>8.1}% {:>7.1}% {:>8}",
            seed,
            r.m3d_freq_ghz,
            gain,
            100.0 * (1.0 - r.energy_ratio),
            r.m3d_critical_stage
        );
    }
    let mean_gain = freq_gains.iter().sum::<f64>() / freq_gains.len() as f64;
    println!("mean frequency gain: {mean_gain:.1}% (paper: 10%)\n");

    // 2. Tier-count ablation on the two critical stages.
    println!("Tier-count ablation (critical path, seed 42):");
    println!("{:<8} {:>10} {:>10} {:>10}", "stage", "planar ps", "2-tier ps", "4-tier ps");
    let proc_ = Process::default();
    for spec in gpu_stage_specs() {
        if spec.name != "simd" && spec.name != "lsu" {
            continue;
        }
        let nl = spec.generate(42);
        let planar = time_block_planar(&proc_, &nl);
        let two = time_block_m3d(&proc_, &nl, &M3dConfig { n_tiers: 2, ..Default::default() });
        let four = time_block_m3d(&proc_, &nl, &M3dConfig { n_tiers: 4, ..Default::default() });
        println!(
            "{:<8} {:>10.1} {:>10.1} {:>10.1}",
            spec.name, planar.critical_ps, two.critical_ps, four.critical_ps
        );
    }

    // 3. Modification ablation: what the paper's two netlist tricks buy.
    println!("\nModification ablation (seed 42, all stages, 2 tiers):");
    println!("{:<10} {:>12} {:>12} {:>10}", "stage", "plain-scale", "+mods ps", "extra%");
    for spec in gpu_stage_specs() {
        let nl = spec.generate(42);
        let plain = M3dConfig { collapse_pairs: false, offload_branches: false, ..Default::default() };
        let full = M3dConfig::default();
        let a = time_block_m3d(&proc_, &nl, &plain).critical_ps;
        let b = time_block_m3d(&proc_, &nl, &full).critical_ps;
        println!("{:<10} {:>12.1} {:>12.1} {:>9.2}%", spec.name, a, b, 100.0 * (1.0 - b / a));
    }

    // 4. Energy decomposition for the largest block.
    let spec = gpu_stage_specs().into_iter().find(|s| s.name == "simd").unwrap();
    let nl = spec.generate(42);
    let (planar_cap, m3d_cap) = block_energy_caps(&proc_, &nl, &M3dConfig::default());
    println!(
        "\nSIMD switched capacitance: planar {:.0} fF -> m3d {:.0} fF ({:.1}% saving)",
        planar_cap,
        m3d_cap,
        100.0 * (1.0 - m3d_cap / planar_cap)
    );
}
