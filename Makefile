# Convenience targets.  `artifacts` needs the L2 Python toolchain (JAX);
# everything else is offline-capable.

.PHONY: build test doc artifacts

build:
	cargo build --release

test:
	cargo test -q

doc:
	cargo doc --no-deps

# AOT-lower the L2 models to artifacts/*.hlo.txt (see python/compile/aot.py).
artifacts:
	cd python && python -m compile.aot --out ../artifacts
