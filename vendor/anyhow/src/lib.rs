//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build image is fully offline (no crates.io index), so the real
//! `anyhow` cannot be fetched.  This vendored crate implements exactly the
//! subset `hem3d` uses — [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros — with the same
//! semantics:
//!
//! * `{err}` displays the outermost message;
//! * `{err:#}` displays the whole context chain joined by `": "`;
//! * `{err:?}` displays the message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//!   via `?`, capturing its `source()` chain.
//!
//! Swapping back to the real crate is a one-line change in `rust/Cargo.toml`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) context; the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost stays last).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: every std error converts via `?`.  (This does not
// overlap with the reflexive `From<T> for T` because `Error` itself never
// implements `std::error::Error`.)
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("opening artifact")
    }

    #[test]
    fn context_chain_formats() {
        let err = fails_io().unwrap_err();
        assert_eq!(format!("{err}"), "opening artifact");
        assert_eq!(format!("{err:#}"), "opening artifact: gone");
        assert!(format!("{err:?}").contains("Caused by:"));
        assert_eq!(err.root_cause(), "gone");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("seven is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "seven is right out");
        let msg = String::from("plain");
        assert_eq!(format!("{}", anyhow!(msg)), "plain");
        assert_eq!(format!("{}", anyhow!("fmt {}", 2)), "fmt 2");
    }

    #[test]
    fn with_context_and_option() {
        let none: Option<u8> = None;
        let err = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{err}"), "missing thing");
        let ok = Some(5u8).context("unused").unwrap();
        assert_eq!(ok, 5);
    }
}
